//! The deadline-aware sharded executor.
//!
//! A [`ShardedServer`] owns one model shard per partition and serves a
//! replayed query log over the batch engine's worker pool. Per batch:
//!
//! 1. **Stage 1** — one pool task per shard computes every query's
//!    initial answer from aggregated points; results stream back in
//!    completion order and are merged per query the moment the last
//!    shard lands. The initial response is *always* delivered.
//! 2. **Budget** — the per-request refinement budget is resolved:
//!    a fixed bucket count, Algorithm 1's ε_max fraction, everything,
//!    or whatever the remaining deadline affords (estimated from the
//!    measured stage-1 cost and the shards' originals-per-bucket).
//! 3. **Stage 2** — one pool task per shard refines the batch with the
//!    resolved budget (Algorithm 1's ranking picks which buckets each
//!    query expands); refined answers are merged into the final
//!    responses.
//!
//! Task panics take the same path as the batch engine
//! ([`crate::mapreduce::engine::drain_stream`]): the first panic fails
//! the replay with an error after draining in-flight tasks.

use std::sync::{mpsc, Arc};

use crate::approx::algorithm1::refine_budget;
use crate::error::{Error, Result};
use crate::mapreduce::engine::{drain_stream, Engine};
use crate::model::{InitialAnswer, ServableModel};
use crate::serve::batcher::MicroBatcher;
use crate::serve::stats::{LatencyStats, ServeReport};
use crate::util::timer::Stopwatch;

/// How much stage-2 work each request may spend, per shard.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RefineBudget {
    /// No refinement: serve the initial answer only.
    Off,
    /// A fixed number of ranked buckets per shard.
    Buckets(usize),
    /// Algorithm 1's ε_max: `refine_budget(n_buckets, eps)` per shard.
    Fraction(f64),
    /// Refine every bucket (the anytime upper bound; equals the exact
    /// answer for kNN/CF/k-means models).
    All,
    /// Spend whatever remains of the request deadline, estimated from
    /// the measured stage-1 cost of the same batch.
    Deadline,
}

/// Serving parameters for one replay.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Queries grouped per shard task (see
    /// [`crate::serve::MicroBatcher`]).
    pub batch_size: usize,
    /// Per-request deadline, seconds from batch dispatch.
    pub deadline_s: f64,
    /// Refinement budget policy.
    pub budget: RefineBudget,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_size: 64,
            deadline_s: 0.050,
            budget: RefineBudget::Fraction(0.05),
        }
    }
}

/// Everything the server did for one request.
#[derive(Clone, Debug)]
pub struct QueryOutcome<R> {
    /// The always-delivered initial response (aggregated points only).
    pub initial: R,
    /// The refined response, when any budget was spent.
    pub refined: Option<R>,
    /// Seconds from batch dispatch to the merged initial response.
    pub initial_latency_s: f64,
    /// Seconds from batch dispatch to the final response.
    pub total_latency_s: f64,
    /// Per-query accuracy of the initial response (ground truth
    /// permitting).
    pub initial_accuracy: Option<f64>,
    /// Per-query accuracy of the refined response.
    pub refined_accuracy: Option<f64>,
    /// Buckets expanded for this request, summed over shards.
    pub refined_buckets: usize,
}

impl<R> QueryOutcome<R> {
    /// The response a client would act on: refined when present,
    /// initial otherwise.
    pub fn final_response(&self) -> &R {
        self.refined.as_ref().unwrap_or(&self.initial)
    }
}

/// A model sharded across the engine's worker pool.
pub struct ShardedServer<M: ServableModel> {
    shards: Vec<Arc<M>>,
}

impl<M: ServableModel> ShardedServer<M> {
    /// Serve from the given shards (at least one).
    pub fn new(shards: Vec<Arc<M>>) -> Result<ShardedServer<M>> {
        if shards.is_empty() {
            return Err(Error::Engine("server needs at least one shard".into()));
        }
        Ok(ShardedServer { shards })
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Replay a query log: batch, answer, refine. Returns the
    /// per-request outcomes (in input order) and the aggregate report.
    pub fn serve(
        &self,
        engine: &Engine,
        queries: Vec<M::Query>,
        config: &ServeConfig,
    ) -> Result<(Vec<QueryOutcome<M::Response>>, ServeReport)> {
        let queries = Arc::new(queries);
        let mut outcomes: Vec<QueryOutcome<M::Response>> =
            Vec::with_capacity(queries.len());
        let mut batcher = MicroBatcher::new(config.batch_size);
        for qi in 0..queries.len() {
            if let Some(batch) = batcher.push(qi) {
                self.serve_batch(engine, &queries, batch, config, &mut outcomes)?;
            }
        }
        if let Some(batch) = batcher.flush() {
            self.serve_batch(engine, &queries, batch, config, &mut outcomes)?;
        }

        let report = self.report(&queries, &outcomes, config);
        Ok((outcomes, report))
    }

    /// One micro-batch through both stages.
    fn serve_batch(
        &self,
        engine: &Engine,
        queries: &Arc<Vec<M::Query>>,
        batch: Vec<usize>,
        config: &ServeConfig,
        outcomes: &mut Vec<QueryOutcome<M::Response>>,
    ) -> Result<()> {
        let n_shards = self.shards.len();
        let batch = Arc::new(batch);
        let sw = Stopwatch::new();

        // Stage 1: every shard answers the whole batch from aggregates.
        let rx1 = engine.pool().stream(n_shards, |s| {
            let shard = Arc::clone(&self.shards[s]);
            let queries = Arc::clone(queries);
            let batch = Arc::clone(&batch);
            move || -> Vec<InitialAnswer<M::Answer>> {
                batch.iter().map(|&qi| shard.answer_initial(&queries[qi])).collect()
            }
        });
        let mut per_shard: Vec<Option<Vec<InitialAnswer<M::Answer>>>> =
            (0..n_shards).map(|_| None).collect();
        let mut failure: Option<Error> = None;
        drain_stream(rx1, "serving stage-1", &mut failure, |s, v, _| {
            per_shard[s] = Some(v);
        });
        if let Some(e) = failure {
            return Err(e);
        }

        // Merge per query: the initial responses, always delivered.
        let merger = &self.shards[0];
        let mut initial_responses: Vec<M::Response> = Vec::with_capacity(batch.len());
        for (j, &qi) in batch.iter().enumerate() {
            let partials: Vec<M::Answer> = per_shard
                .iter()
                .map(|s| s.as_ref().expect("shard answer missing")[j].answer.clone())
                .collect();
            initial_responses.push(merger.merge(&queries[qi], &partials));
        }
        // The client-visible initial-response time: stage 1 *plus* the
        // merge that produces the deliverable answer.
        let initial_latency_s = sw.elapsed_s();

        // Resolve the per-shard refinement budgets.
        let budgets = self.resolve_budgets(config, initial_latency_s, batch.len());
        let refined_buckets: usize = budgets
            .iter()
            .enumerate()
            .map(|(s, &b)| b.min(self.shards[s].n_buckets()))
            .sum();

        if budgets.iter().all(|&b| b == 0) {
            // Initial answers are final.
            for (&qi, initial) in batch.iter().zip(initial_responses) {
                let initial_accuracy = merger.accuracy(&queries[qi], &initial);
                outcomes.push(QueryOutcome {
                    initial,
                    refined: None,
                    initial_latency_s,
                    total_latency_s: initial_latency_s,
                    initial_accuracy,
                    refined_accuracy: None,
                    refined_buckets: 0,
                });
            }
            return Ok(());
        }

        // Stage 2: every shard refines the whole batch with its budget,
        // consuming the stage-1 answers it produced.
        let (tx2, rx2) = mpsc::channel();
        for (s, slot) in per_shard.iter_mut().enumerate() {
            let initials = slot.take().expect("shard answer missing");
            let shard = Arc::clone(&self.shards[s]);
            let queries = Arc::clone(queries);
            let batch = Arc::clone(&batch);
            let budget = budgets[s];
            engine.pool().stream_into(&tx2, s, move || -> Vec<M::Answer> {
                batch
                    .iter()
                    .zip(&initials)
                    .map(|(&qi, initial)| shard.refine(&queries[qi], initial, budget))
                    .collect()
            });
        }
        drop(tx2);
        let mut refined_per_shard: Vec<Option<Vec<M::Answer>>> =
            (0..n_shards).map(|_| None).collect();
        let mut failure: Option<Error> = None;
        drain_stream(rx2, "serving stage-2", &mut failure, |s, v, _| {
            refined_per_shard[s] = Some(v);
        });
        if let Some(e) = failure {
            return Err(e);
        }
        let total_latency_s = sw.elapsed_s();

        for ((j, &qi), initial) in batch.iter().enumerate().zip(initial_responses) {
            let partials: Vec<M::Answer> = refined_per_shard
                .iter()
                .map(|s| s.as_ref().expect("shard refinement missing")[j].clone())
                .collect();
            let refined = merger.merge(&queries[qi], &partials);
            let initial_accuracy = merger.accuracy(&queries[qi], &initial);
            let refined_accuracy = merger.accuracy(&queries[qi], &refined);
            outcomes.push(QueryOutcome {
                initial,
                refined: Some(refined),
                initial_latency_s,
                total_latency_s,
                initial_accuracy,
                refined_accuracy,
                refined_buckets,
            });
        }
        Ok(())
    }

    /// Per-shard stage-2 budgets under the configured policy.
    /// `elapsed_s` is the batch's dispatch-to-initial-response time —
    /// it both anchors the remaining-deadline check and calibrates the
    /// per-bucket cost estimate.
    fn resolve_budgets(
        &self,
        config: &ServeConfig,
        elapsed_s: f64,
        batch_len: usize,
    ) -> Vec<usize> {
        match config.budget {
            RefineBudget::Off => vec![0; self.shards.len()],
            RefineBudget::Buckets(n) => vec![n; self.shards.len()],
            RefineBudget::All => {
                self.shards.iter().map(|s| s.n_buckets()).collect()
            }
            RefineBudget::Fraction(eps) => self
                .shards
                .iter()
                .map(|s| refine_budget(s.n_buckets(), eps))
                .collect(),
            RefineBudget::Deadline => {
                let remaining = config.deadline_s - elapsed_s;
                if remaining <= 0.0 {
                    return vec![0; self.shards.len()];
                }
                // Stage 1 scored every aggregated bucket once per query;
                // refining a bucket rescans its originals, so one
                // refined bucket costs roughly (originals / buckets) ×
                // the per-bucket stage-1 cost. Divide the remaining
                // time evenly across shards.
                let total_buckets: usize =
                    self.shards.iter().map(|s| s.n_buckets().max(1)).sum();
                let per_bucket_s = (elapsed_s
                    / (batch_len.max(1) * total_buckets.max(1)) as f64)
                    .max(1e-9);
                self.shards
                    .iter()
                    .map(|s| {
                        let per_refined_bucket_s = per_bucket_s
                            * (s.n_originals().max(1) as f64 / s.n_buckets().max(1) as f64);
                        let affordable = remaining
                            / (self.shards.len().max(1) * batch_len.max(1)) as f64
                            / per_refined_bucket_s;
                        (affordable.floor() as usize).min(s.n_buckets())
                    })
                    .collect()
            }
        }
    }

    /// Aggregate the outcomes into a [`ServeReport`].
    fn report(
        &self,
        queries: &Arc<Vec<M::Query>>,
        outcomes: &[QueryOutcome<M::Response>],
        config: &ServeConfig,
    ) -> ServeReport {
        let mean_of = |xs: Vec<f64>| {
            if xs.is_empty() {
                None
            } else {
                Some(xs.iter().sum::<f64>() / xs.len() as f64)
            }
        };
        let refined_queries = outcomes.iter().filter(|o| o.refined.is_some()).count();
        let refined_buckets_mean = if refined_queries > 0 {
            outcomes.iter().map(|o| o.refined_buckets as f64).sum::<f64>()
                / refined_queries as f64
        } else {
            0.0
        };
        ServeReport {
            queries: queries.len(),
            shards: self.shards.len(),
            initial: LatencyStats::from_samples(
                outcomes.iter().map(|o| o.initial_latency_s).collect(),
            ),
            total: LatencyStats::from_samples(
                outcomes.iter().map(|o| o.total_latency_s).collect(),
            ),
            initial_accuracy: mean_of(
                outcomes.iter().filter_map(|o| o.initial_accuracy).collect(),
            ),
            // Final-response accuracy over the SAME population as the
            // initial mean: unrefined queries contribute their initial
            // accuracy, so partial refinement (e.g. Deadline budgets
            // under load) cannot skew the comparison by averaging over
            // an easier subset.
            refined_accuracy: mean_of(
                outcomes
                    .iter()
                    .filter_map(|o| o.refined_accuracy.or(o.initial_accuracy))
                    .collect(),
            ),
            refined_queries,
            refined_buckets_mean,
            deadline_misses: outcomes
                .iter()
                .filter(|o| o.initial_latency_s > config.deadline_s)
                .count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::InitialAnswer;

    /// Toy shard: buckets hold integers; the initial answer is the
    /// bucket-max, refinement reveals the true max of expanded buckets.
    /// Ground truth is the query's `target`.
    struct ToyModel {
        /// Per-bucket (aggregate_value, exact_value).
        buckets: Vec<(i64, i64)>,
        panic_on_refine: bool,
    }

    #[derive(Clone, Debug)]
    struct ToyQuery {
        target: i64,
    }

    impl ServableModel for ToyModel {
        type Query = ToyQuery;
        type Answer = i64;
        type Response = i64;

        fn n_buckets(&self) -> usize {
            self.buckets.len()
        }

        fn n_originals(&self) -> usize {
            self.buckets.len() * 4
        }

        fn answer_initial(&self, _q: &ToyQuery) -> InitialAnswer<i64> {
            let answer = self.buckets.iter().map(|b| b.0).max().unwrap_or(0);
            // Rank buckets by their aggregate value.
            let correlations = self.buckets.iter().map(|b| b.0 as f32).collect();
            InitialAnswer {
                answer,
                correlations,
            }
        }

        fn refine(&self, _q: &ToyQuery, initial: &InitialAnswer<i64>, budget: usize) -> i64 {
            if self.panic_on_refine {
                panic!("injected refine fault");
            }
            let chosen =
                crate::approx::algorithm1::refinement_order(&initial.correlations, budget);
            let mut best = initial.answer;
            for b in chosen {
                best = best.max(self.buckets[b].1);
            }
            best
        }

        fn merge(&self, _q: &ToyQuery, partials: &[i64]) -> i64 {
            partials.iter().copied().max().unwrap_or(0)
        }

        fn accuracy(&self, q: &ToyQuery, r: &i64) -> Option<f64> {
            Some(-((q.target - r).abs() as f64))
        }
    }

    fn server(panic_on_refine: bool) -> ShardedServer<ToyModel> {
        ShardedServer::new(vec![
            Arc::new(ToyModel {
                buckets: vec![(5, 9), (3, 4), (1, 1)],
                panic_on_refine,
            }),
            Arc::new(ToyModel {
                buckets: vec![(2, 2), (4, 12)],
                panic_on_refine,
            }),
        ])
        .unwrap()
    }

    fn queries(n: usize) -> Vec<ToyQuery> {
        (0..n).map(|_| ToyQuery { target: 12 }).collect()
    }

    #[test]
    fn rejects_empty_shard_set() {
        assert!(ShardedServer::<ToyModel>::new(vec![]).is_err());
    }

    #[test]
    fn initial_only_when_budget_off() {
        let engine = Engine::new(2);
        let (outcomes, report) = server(false)
            .serve(
                &engine,
                queries(5),
                &ServeConfig {
                    batch_size: 2,
                    deadline_s: 10.0,
                    budget: RefineBudget::Off,
                },
            )
            .unwrap();
        assert_eq!(outcomes.len(), 5);
        for o in &outcomes {
            assert_eq!(o.initial, 5, "initial = max of aggregates");
            assert!(o.refined.is_none());
            assert_eq!(o.refined_buckets, 0);
            assert_eq!(*o.final_response(), 5);
        }
        assert_eq!(report.refined_queries, 0);
        assert_eq!(report.deadline_misses, 0);
        assert_eq!(report.queries, 5);
        assert_eq!(report.shards, 2);
    }

    #[test]
    fn full_budget_recovers_the_exact_answer() {
        let engine = Engine::new(2);
        let (outcomes, report) = server(false)
            .serve(
                &engine,
                queries(7),
                &ServeConfig {
                    batch_size: 3,
                    deadline_s: 10.0,
                    budget: RefineBudget::All,
                },
            )
            .unwrap();
        for o in &outcomes {
            assert_eq!(o.initial, 5);
            assert_eq!(o.refined, Some(12), "exact max after full refinement");
            assert!(o.total_latency_s >= o.initial_latency_s);
            assert_eq!(o.refined_buckets, 5, "all buckets of both shards");
        }
        // Ground truth is 12: refined is exact, initial is off by 7.
        assert_eq!(report.refined_accuracy, Some(0.0));
        assert_eq!(report.initial_accuracy, Some(-7.0));
        assert!(report.refined_accuracy >= report.initial_accuracy);
    }

    #[test]
    fn fixed_bucket_budget_is_partial() {
        let engine = Engine::new(2);
        let (outcomes, _) = server(false)
            .serve(
                &engine,
                queries(1),
                &ServeConfig {
                    batch_size: 1,
                    deadline_s: 10.0,
                    budget: RefineBudget::Buckets(1),
                },
            )
            .unwrap();
        // Shard 0 expands its top aggregate bucket (5 -> 9); shard 1
        // expands (4 -> 12). Merge = 12.
        assert_eq!(outcomes[0].refined, Some(12));
        assert_eq!(outcomes[0].refined_buckets, 2);
    }

    #[test]
    fn zero_deadline_counts_misses_but_still_answers() {
        let engine = Engine::new(2);
        let (outcomes, report) = server(false)
            .serve(
                &engine,
                queries(4),
                &ServeConfig {
                    batch_size: 4,
                    deadline_s: 0.0,
                    budget: RefineBudget::Deadline,
                },
            )
            .unwrap();
        assert_eq!(outcomes.len(), 4, "initial answers always delivered");
        assert_eq!(report.deadline_misses, 4);
        for o in &outcomes {
            assert!(o.refined.is_none(), "no budget left past the deadline");
        }
    }

    #[test]
    fn refine_panic_fails_the_replay_without_hanging() {
        let engine = Engine::new(2);
        let err = server(true)
            .serve(
                &engine,
                queries(3),
                &ServeConfig {
                    batch_size: 3,
                    deadline_s: 10.0,
                    budget: RefineBudget::All,
                },
            )
            .unwrap_err();
        assert!(err.to_string().contains("serving stage-2"), "{err}");
        // The engine stays usable afterwards.
        let (outcomes, _) = server(false)
            .serve(&engine, queries(2), &ServeConfig::default())
            .unwrap();
        assert_eq!(outcomes.len(), 2);
    }
}
