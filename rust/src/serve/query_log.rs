//! Synthetic query logs for replay.
//!
//! Serving is exercised by replaying deterministic logs derived from
//! the workbench datasets: kNN queries are held-out test points (with
//! their labels as ground truth), CF queries are held-out (user, item)
//! ratings, k-means queries are jittered training points. Logs longer
//! than the source data cycle through it — a skew-free stand-in for
//! repeat traffic.

use std::collections::HashMap;
use std::sync::Arc;

use crate::data::gaussian::LabeledPoints;
use crate::data::matrix::Matrix;
use crate::data::ratings::RatingsSplit;
use crate::model::cf::CfQuery;
use crate::model::kmeans::KmeansQuery;
use crate::model::knn::KnnQuery;
use crate::util::rng::Rng;

/// `n` kNN queries cycling over the held-out test points. Per-query
/// seeds mirror the batch job's plan seeds (`seed ^ test_row`).
pub fn knn_query_log(data: &LabeledPoints, n: usize, seed: u64) -> Vec<KnnQuery> {
    let n_test = data.test.rows().max(1);
    (0..n)
        .map(|i| {
            let t = i % n_test;
            KnnQuery {
                features: data.test.row(t).to_vec(),
                label: Some(data.test_labels[t]),
                seed: seed ^ t as u64,
            }
        })
        .collect()
}

/// `n` CF queries cycling over the held-out (user, item, rating)
/// triplets. Each query carries the user's centered row + mask + mean
/// and excludes the user from their own neighborhood. The dense row
/// and mask are built once per distinct user and `Arc`-shared across
/// the repeats, so the log is O(distinct users) in memory, not O(n).
pub fn cf_query_log(split: &RatingsSplit, n: usize, seed: u64) -> Vec<CfQuery> {
    let n_test = split.test.len().max(1);
    let m = split.train.n_items();
    let mut rows: HashMap<u32, (Arc<Vec<f32>>, Arc<Vec<f32>>, f32)> = HashMap::new();
    (0..n)
        .map(|i| {
            let (u, item, actual) = split.test[i % n_test];
            let (cu, mu, mean) = rows
                .entry(u)
                .or_insert_with(|| {
                    let (cu, mean) = split.train.centered_row(u as usize);
                    let mut mu = vec![0.0f32; m];
                    for &it in &split.train.rated[u as usize] {
                        mu[it as usize] = 1.0;
                    }
                    (Arc::new(cu), Arc::new(mu), mean)
                })
                .clone();
            CfQuery {
                cu,
                mu,
                mean,
                item,
                exclude: Some(u),
                actual: Some(actual),
                seed: seed ^ i as u64,
            }
        })
        .collect()
}

/// `n` k-means queries: training points with a little Gaussian jitter,
/// so queries sit near (not on) the data manifold.
pub fn kmeans_query_log(points: &Matrix, n: usize, seed: u64) -> Vec<KmeansQuery> {
    let mut rng = Rng::new(seed ^ 0x5E4E);
    let rows = points.rows().max(1);
    (0..n)
        .map(|i| {
            let r = rng.index(rows);
            let mut point = points.row(r).to_vec();
            for v in point.iter_mut() {
                *v += rng.normal() as f32 * 0.05;
            }
            KmeansQuery {
                point,
                seed: seed ^ i as u64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixtureSpec;
    use crate::data::ratings::LatentFactorSpec;

    #[test]
    fn knn_log_cycles_and_carries_labels() {
        let d = GaussianMixtureSpec {
            n_points: 300,
            dim: 4,
            n_classes: 2,
            test_fraction: 0.05,
            ..Default::default()
        }
        .generate()
        .unwrap();
        let n_test = d.test.rows();
        let log = knn_query_log(&d, n_test * 2 + 3, 9);
        assert_eq!(log.len(), n_test * 2 + 3);
        assert_eq!(log[0].features, log[n_test].features);
        assert!(log.iter().all(|q| q.label.is_some()));
    }

    #[test]
    fn cf_log_matches_heldout_and_is_deterministic() {
        let m = LatentFactorSpec {
            n_users: 120,
            n_items: 48,
            mean_ratings_per_user: 10,
            ..Default::default()
        }
        .generate()
        .unwrap();
        let split = RatingsSplit::new(&m, 8, 0.2, 3).unwrap();
        let a = cf_query_log(&split, 20, 5);
        let b = cf_query_log(&split, 20, 5);
        assert_eq!(a.len(), 20);
        assert_eq!(a[0].item, b[0].item);
        assert_eq!(a[0].cu, b[0].cu);
        assert!(a.iter().all(|q| q.actual.is_some() && q.exclude.is_some()));
    }

    #[test]
    fn kmeans_log_jitters_points_deterministically() {
        let pts = Matrix::from_vec(4, 2, vec![0., 0., 1., 1., 2., 2., 3., 3.]).unwrap();
        let a = kmeans_query_log(&pts, 10, 1);
        let b = kmeans_query_log(&pts, 10, 1);
        assert_eq!(a.len(), 10);
        for (qa, qb) in a.iter().zip(&b) {
            assert_eq!(qa.point, qb.point);
        }
    }
}
