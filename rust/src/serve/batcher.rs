//! Request micro-batching.
//!
//! A shard answers a whole batch of queries in one pool task — and,
//! since the model layer's `answer_initial_block`, in ONE backend call
//! — so both the per-task overhead (submission, channel send,
//! scheduling) and the per-call scoring overhead amortize across the
//! batch instead of being paid per query: the standard serving trade of
//! a little queueing latency for a lot of throughput. The executor's
//! hot-query answer cache sits *in front* of this batcher; only cache
//! misses are admitted.
//!
//! Two release triggers:
//!
//! * **size** — the window fills ([`MicroBatcher::push`] returns the
//!   batch);
//! * **time** — the oldest pending request has waited longer than the
//!   configured `max_wait_s` ([`MicroBatcher::flush_expired`]), which
//!   bounds the queueing latency a partial batch can accrue while the
//!   executor is busy serving, rebuilding shards, or waiting on sparse
//!   arrivals. `max_wait_s <= 0` (the [`MicroBatcher::new`] default)
//!   disables the time trigger: release on size only.

use crate::util::timer::Stopwatch;

/// Accumulates requests and releases them in fixed-size batches, with
/// an optional cap on how long the oldest request may queue.
#[derive(Debug)]
pub struct MicroBatcher<Q> {
    capacity: usize,
    max_wait_s: f64,
    pending: Vec<Q>,
    /// Started when the first request of the current window arrives.
    oldest: Option<Stopwatch>,
}

impl<Q> MicroBatcher<Q> {
    /// Batcher releasing batches of `capacity` (clamped to >= 1) on
    /// size only.
    pub fn new(capacity: usize) -> MicroBatcher<Q> {
        MicroBatcher::with_max_wait(capacity, 0.0)
    }

    /// Batcher that additionally expires a partial window once its
    /// oldest request has waited `max_wait_s` seconds (`<= 0` disables
    /// the time trigger).
    pub fn with_max_wait(capacity: usize, max_wait_s: f64) -> MicroBatcher<Q> {
        let capacity = capacity.max(1);
        MicroBatcher {
            capacity,
            max_wait_s,
            pending: Vec::with_capacity(capacity),
            oldest: None,
        }
    }

    /// Enqueue one request; returns a full batch when the window fills.
    pub fn push(&mut self, q: Q) -> Option<Vec<Q>> {
        if self.pending.is_empty() {
            self.oldest = Some(Stopwatch::new());
        }
        self.pending.push(q);
        if self.pending.len() >= self.capacity {
            self.oldest = None;
            Some(std::mem::replace(
                &mut self.pending,
                Vec::with_capacity(self.capacity),
            ))
        } else {
            None
        }
    }

    /// Release whatever is queued (end of the replay / timeout tick).
    pub fn flush(&mut self) -> Option<Vec<Q>> {
        self.oldest = None;
        if self.pending.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.pending))
        }
    }

    /// Whether the oldest pending request has exceeded the batcher's
    /// max wait (always false when the time trigger is disabled or
    /// nothing is pending).
    pub fn expired(&self) -> bool {
        self.max_wait_s > 0.0
            && self
                .oldest
                .as_ref()
                .is_some_and(|sw| sw.elapsed_s() >= self.max_wait_s)
    }

    /// Release the pending window iff it has expired (the time-based
    /// flush the serving loop polls between admissions).
    pub fn flush_expired(&mut self) -> Option<Vec<Q>> {
        if self.expired() {
            self.flush()
        } else {
            None
        }
    }

    /// Requests currently queued.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// No requests queued.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// The batch window.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The time trigger (seconds; `<= 0` = disabled).
    pub fn max_wait_s(&self) -> f64 {
        self.max_wait_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn releases_full_batches_in_order() {
        let mut b = MicroBatcher::new(3);
        assert_eq!(b.push(1), None);
        assert_eq!(b.push(2), None);
        assert_eq!(b.push(3), Some(vec![1, 2, 3]));
        assert_eq!(b.pending(), 0);
        assert!(b.is_empty());
        assert_eq!(b.push(4), None);
        assert!(!b.is_empty());
        assert_eq!(b.flush(), Some(vec![4]));
        assert_eq!(b.flush(), None);
    }

    #[test]
    fn zero_capacity_degenerates_to_per_query_batches() {
        let mut b = MicroBatcher::new(0);
        assert_eq!(b.capacity(), 1);
        assert_eq!(b.push(7), Some(vec![7]));
    }

    #[test]
    fn size_only_batcher_never_expires() {
        let mut b = MicroBatcher::new(4);
        assert_eq!(b.max_wait_s(), 0.0);
        b.push(1);
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(!b.expired());
        assert_eq!(b.flush_expired(), None);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn partial_window_expires_after_max_wait() {
        let mut b = MicroBatcher::with_max_wait(4, 0.001);
        assert!(!b.expired(), "nothing pending yet");
        b.push(1);
        b.push(2);
        std::thread::sleep(std::time::Duration::from_millis(3));
        assert!(b.expired());
        assert_eq!(b.flush_expired(), Some(vec![1, 2]));
        assert!(!b.expired(), "flush resets the window clock");
        assert_eq!(b.flush_expired(), None);
    }

    #[test]
    fn filling_a_window_resets_the_clock() {
        let mut b = MicroBatcher::with_max_wait(2, 0.001);
        b.push(1);
        std::thread::sleep(std::time::Duration::from_millis(3));
        assert_eq!(b.push(2), Some(vec![1, 2]), "size trigger still wins");
        // The next window starts fresh: not expired until ITS oldest
        // request has waited long enough.
        b.push(3);
        assert!(!b.expired());
        std::thread::sleep(std::time::Duration::from_millis(3));
        assert!(b.expired());
        assert_eq!(b.flush_expired(), Some(vec![3]));
    }
}
