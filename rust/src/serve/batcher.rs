//! Request micro-batching.
//!
//! A shard answers a whole batch of queries in one pool task — and,
//! since the model layer's `answer_initial_block`, in ONE backend call
//! — so both the per-task overhead (submission, channel send,
//! scheduling) and the per-call scoring overhead amortize across the
//! batch instead of being paid per query: the standard serving trade of
//! a little queueing latency for a lot of throughput. The executor's
//! hot-query answer cache sits *in front* of this batcher; only cache
//! misses are admitted.

/// Accumulates requests and releases them in fixed-size batches.
#[derive(Debug)]
pub struct MicroBatcher<Q> {
    capacity: usize,
    pending: Vec<Q>,
}

impl<Q> MicroBatcher<Q> {
    /// Batcher releasing batches of `capacity` (clamped to >= 1).
    pub fn new(capacity: usize) -> MicroBatcher<Q> {
        let capacity = capacity.max(1);
        MicroBatcher {
            capacity,
            pending: Vec::with_capacity(capacity),
        }
    }

    /// Enqueue one request; returns a full batch when the window fills.
    pub fn push(&mut self, q: Q) -> Option<Vec<Q>> {
        self.pending.push(q);
        if self.pending.len() >= self.capacity {
            Some(std::mem::replace(
                &mut self.pending,
                Vec::with_capacity(self.capacity),
            ))
        } else {
            None
        }
    }

    /// Release whatever is queued (end of the replay / timeout tick).
    pub fn flush(&mut self) -> Option<Vec<Q>> {
        if self.pending.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.pending))
        }
    }

    /// Requests currently queued.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// No requests queued.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// The batch window.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn releases_full_batches_in_order() {
        let mut b = MicroBatcher::new(3);
        assert_eq!(b.push(1), None);
        assert_eq!(b.push(2), None);
        assert_eq!(b.push(3), Some(vec![1, 2, 3]));
        assert_eq!(b.pending(), 0);
        assert!(b.is_empty());
        assert_eq!(b.push(4), None);
        assert!(!b.is_empty());
        assert_eq!(b.flush(), Some(vec![4]));
        assert_eq!(b.flush(), None);
    }

    #[test]
    fn zero_capacity_degenerates_to_per_query_batches() {
        let mut b = MicroBatcher::new(0);
        assert_eq!(b.capacity(), 1);
        assert_eq!(b.push(7), Some(vec![7]));
    }
}
