//! The JSONL wire protocol spoken by the serving daemon.
//!
//! One message per line, each line one compact JSON object carrying a
//! `"type"` tag — hand-rolled over [`crate::util::json::Json`], zero
//! external dependencies. Client→server messages are [`Request`]s
//! (`query`, `ingest`, `stats`, `metrics`, `shutdown`); server→client
//! messages are [`Reply`]s (`response`, `ingested`, `stats`, `metrics`,
//! `shutdown`, `error`).
//! Both directions round-trip through [`Request::to_line`] /
//! [`Request::parse_line`] (and the `Reply` equivalents), which is what
//! lets the load generator ([`crate::serve::loadgen`]) parse the
//! daemon's output with the same code the daemon used to write it.
//!
//! App-specific payloads (what a kNN query *is*, what a CF delta *is*)
//! are translated by a [`WireCodec`]: the envelope stays generic over
//! [`Refreshable`] models while [`KnnWire`], [`CfWire`] and
//! [`KmeansWire`] map JSON bodies to the concrete query/delta types.
//! Codecs hold the dataset context (`Arc`s of the workbench data), so a
//! client can address queries by held-out row index (`test_row`/`row`)
//! — the form the Zipf-keyed load generator uses, and the one that
//! makes repeat hot keys produce byte-identical
//! [`query_key`](crate::model::ServableModel::query_key)s for the
//! answer cache — or ship explicit feature vectors.
//!
//! Malformed input yields [`Error`]s, never panics: the daemon turns a
//! bad line into an `error` reply and keeps serving the connection.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::data::gaussian::LabeledPoints;
use crate::data::matrix::Matrix;
use crate::data::ratings::RatingsSplit;
use crate::error::{Error, Result};
use crate::model::cf::CfQuery;
use crate::model::kmeans::{KmeansQuery, RepMatch};
use crate::model::knn::KnnQuery;
use crate::model::{CfModel, KmeansModel, KnnModel};
use crate::refresh::{LabeledPoint, Refreshable};
use crate::serve::executor::QueryOutcome;
use crate::serve::stats::ServeTracePoint;
use crate::util::json::Json;

/// One client→server message, parsed from one line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Answer one query. `id` is echoed on the response so clients can
    /// pipeline; `body` is the app-specific payload (everything in the
    /// line except the `type`/`id` envelope keys), decoded by a
    /// [`WireCodec`].
    Query { id: u64, body: Json },
    /// Ingest model deltas: the body's `"deltas"` array is decoded
    /// element-wise by [`WireCodec::delta_from_json`] and appended to
    /// the daemon's delta log, triggering a background rebuild.
    Ingest { body: Json },
    /// Ask for a `stats` reply (counters, queue depth, latency
    /// percentiles, the active [`ServeConfig`](super::ServeConfig)).
    Stats,
    /// Ask for a `metrics` reply: the live observability registry
    /// snapshot ([`crate::obs::snapshot_json`]).
    Metrics,
    /// Drain in-flight queries, ack with a `shutdown` reply, exit.
    Shutdown,
}

impl Request {
    /// Convenience constructor: a `query` whose body is built from
    /// key/value pairs.
    pub fn query(id: u64, body: Vec<(&str, Json)>) -> Request {
        Request::Query {
            id,
            body: Json::obj(body),
        }
    }

    /// Encode as one compact JSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Request::Query { id, body } => {
                let mut m = body_map(body);
                m.insert("type".to_string(), Json::from("query"));
                m.insert("id".to_string(), Json::from(*id as f64));
                Json::Obj(m).compact()
            }
            Request::Ingest { body } => {
                let mut m = body_map(body);
                m.insert("type".to_string(), Json::from("ingest"));
                Json::Obj(m).compact()
            }
            Request::Stats => Json::obj(vec![("type", "stats".into())]).compact(),
            Request::Metrics => Json::obj(vec![("type", "metrics".into())]).compact(),
            Request::Shutdown => Json::obj(vec![("type", "shutdown".into())]).compact(),
        }
    }

    /// Decode one line. Unknown types, missing fields and non-object
    /// lines are [`Error`]s, never panics.
    pub fn parse_line(line: &str) -> Result<Request> {
        let v = Json::parse(line.trim())?;
        let Json::Obj(mut m) = v else {
            return Err(wire_err("request line is not a JSON object"));
        };
        let ty = take_type(&mut m)?;
        match ty.as_str() {
            "query" => {
                let id = take_u64(&mut m, "id")?;
                Ok(Request::Query {
                    id,
                    body: Json::Obj(m),
                })
            }
            "ingest" => Ok(Request::Ingest { body: Json::Obj(m) }),
            "stats" => Ok(Request::Stats),
            "metrics" => Ok(Request::Metrics),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(wire_err(&format!("unknown request type {other:?}"))),
        }
    }
}

/// One server→client message, encoded as one line.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// The answer to a `query`, echoing its `id`. Latencies are in
    /// milliseconds; `queue_ms` is the slice spent waiting for dispatch
    /// (already included in `initial_ms`/`total_ms`); `trace` is the
    /// per-request anytime checkpoint array.
    Response {
        id: u64,
        generation: u64,
        cache_hit: bool,
        during_rebuild: bool,
        queue_ms: f64,
        initial_ms: f64,
        total_ms: f64,
        initial: Json,
        refined: Option<Json>,
        trace: Json,
    },
    /// Ack for an `ingest`: deltas accepted into the log, plus the
    /// generation serving *at ack time* (the rebuild lands later — poll
    /// responses for the bump).
    Ingested { accepted: usize, generation: u64 },
    /// Counters and config snapshot.
    Stats { body: Json },
    /// Live observability registry snapshot (counters, gauges,
    /// histograms, flight recorder — see [`crate::obs::snapshot_json`]).
    Metrics { body: Json },
    /// Shutdown ack: total queries served over the daemon's life.
    Shutdown { served: u64 },
    /// A rejected line; `id` is present when the offending line was a
    /// well-formed `query` envelope with a bad body.
    Error { id: Option<u64>, message: String },
}

impl Reply {
    /// Encode as one compact JSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Reply::Response {
                id,
                generation,
                cache_hit,
                during_rebuild,
                queue_ms,
                initial_ms,
                total_ms,
                initial,
                refined,
                trace,
            } => Json::obj(vec![
                ("type", "response".into()),
                ("id", Json::Num(*id as f64)),
                ("generation", Json::Num(*generation as f64)),
                ("cache_hit", (*cache_hit).into()),
                ("during_rebuild", (*during_rebuild).into()),
                ("queue_ms", (*queue_ms).into()),
                ("initial_ms", (*initial_ms).into()),
                ("total_ms", (*total_ms).into()),
                ("initial", initial.clone()),
                ("refined", refined.clone().unwrap_or(Json::Null)),
                ("trace", trace.clone()),
            ])
            .compact(),
            Reply::Ingested {
                accepted,
                generation,
            } => Json::obj(vec![
                ("type", "ingested".into()),
                ("accepted", (*accepted).into()),
                ("generation", Json::Num(*generation as f64)),
            ])
            .compact(),
            Reply::Stats { body } => {
                let mut m = body_map(body);
                m.insert("type".to_string(), Json::from("stats"));
                Json::Obj(m).compact()
            }
            Reply::Metrics { body } => {
                let mut m = body_map(body);
                m.insert("type".to_string(), Json::from("metrics"));
                Json::Obj(m).compact()
            }
            Reply::Shutdown { served } => Json::obj(vec![
                ("type", "shutdown".into()),
                ("served", Json::Num(*served as f64)),
            ])
            .compact(),
            Reply::Error { id, message } => {
                let mut pairs = vec![("type", Json::from("error"))];
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                pairs.push(("message", Json::from(message.as_str())));
                Json::obj(pairs).compact()
            }
        }
    }

    /// Decode one line (the load generator's half of the protocol).
    pub fn parse_line(line: &str) -> Result<Reply> {
        let v = Json::parse(line.trim())?;
        let Json::Obj(mut m) = v else {
            return Err(wire_err("reply line is not a JSON object"));
        };
        let ty = take_type(&mut m)?;
        match ty.as_str() {
            "response" => {
                let v = Json::Obj(m);
                let refined = match v.get("refined") {
                    None | Some(Json::Null) => None,
                    Some(r) => Some(r.clone()),
                };
                Ok(Reply::Response {
                    id: u64_field(&v, "id")?,
                    generation: u64_field(&v, "generation")?,
                    cache_hit: bool_field(&v, "cache_hit")?,
                    during_rebuild: bool_field(&v, "during_rebuild")?,
                    queue_ms: v.num_of("queue_ms")?,
                    initial_ms: v.num_of("initial_ms")?,
                    total_ms: v.num_of("total_ms")?,
                    initial: v
                        .get("initial")
                        .cloned()
                        .ok_or_else(|| wire_err("response missing initial"))?,
                    refined,
                    trace: v.get("trace").cloned().unwrap_or(Json::Arr(Vec::new())),
                })
            }
            "ingested" => {
                let v = Json::Obj(m);
                Ok(Reply::Ingested {
                    accepted: u64_field(&v, "accepted")? as usize,
                    generation: u64_field(&v, "generation")?,
                })
            }
            "stats" => Ok(Reply::Stats { body: Json::Obj(m) }),
            "metrics" => Ok(Reply::Metrics { body: Json::Obj(m) }),
            "shutdown" => {
                let v = Json::Obj(m);
                Ok(Reply::Shutdown {
                    served: u64_field(&v, "served")?,
                })
            }
            "error" => {
                let v = Json::Obj(m);
                let id = match v.get("id") {
                    Some(n) => Some(json_u64(n, "id")?),
                    None => None,
                };
                Ok(Reply::Error {
                    id,
                    message: v.str_of("message")?.to_string(),
                })
            }
            other => Err(wire_err(&format!("unknown reply type {other:?}"))),
        }
    }
}

/// Build the `response` reply for a served outcome. The outcome's
/// latencies already include `queue_wait_s` (the push-mode executor
/// folds queue time into them); the wait is also surfaced separately
/// as `queue_ms`.
pub fn response_reply<R>(
    id: u64,
    queue_wait_s: f64,
    outcome: &QueryOutcome<R>,
    to_json: impl Fn(&R) -> Json,
) -> Reply {
    Reply::Response {
        id,
        generation: outcome.generation,
        cache_hit: outcome.cache_hit,
        during_rebuild: outcome.during_rebuild,
        queue_ms: queue_wait_s * 1e3,
        initial_ms: outcome.initial_latency_s * 1e3,
        total_ms: outcome.total_latency_s * 1e3,
        initial: to_json(&outcome.initial),
        refined: outcome.refined.as_ref().map(&to_json),
        trace: trace_json(&outcome.trace),
    }
}

/// The per-request anytime checkpoints as a JSON array.
pub fn trace_json(trace: &[ServeTracePoint]) -> Json {
    Json::Arr(
        trace
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("stage", t.stage.name().into()),
                    ("wall_ms", (t.wall_s * 1e3).into()),
                    ("accuracy", t.accuracy.map(Json::Num).unwrap_or(Json::Null)),
                    ("refined_buckets", t.refined_buckets.into()),
                ])
            })
            .collect(),
    )
}

/// App-specific translation between wire JSON and a model's
/// query/response/delta types. `Send + Sync + 'static` because the
/// daemon's per-connection reader threads decode with a shared codec.
pub trait WireCodec<M: Refreshable>: Send + Sync + 'static {
    /// Short app tag for stats/reports ("knn", "cf", "kmeans").
    fn app(&self) -> &'static str;
    /// Decode a `query` body into a model query.
    fn query_from_json(&self, body: &Json) -> Result<M::Query>;
    /// Encode a response for the wire.
    fn response_to_json(&self, response: &M::Response) -> Json;
    /// Decode one element of an `ingest` body's `"deltas"` array.
    fn delta_from_json(&self, body: &Json) -> Result<M::Delta>;
}

/// kNN codec. Queries: `{"test_row": T}` (cycles over held-out test
/// points, exactly like [`super::query_log::knn_query_log`], so repeat
/// keys cache-hit) or `{"features": [...], "label"?: L}`. Deltas:
/// `{"features": [...], "label": L}`.
#[derive(Clone)]
pub struct KnnWire {
    /// Workbench dataset the row-indexed form addresses into.
    pub data: Arc<LabeledPoints>,
    /// Base seed folded into per-query plan seeds.
    pub seed: u64,
}

impl WireCodec<KnnModel> for KnnWire {
    fn app(&self) -> &'static str {
        "knn"
    }

    fn query_from_json(&self, body: &Json) -> Result<KnnQuery> {
        if body.get("features").is_some() {
            let features = f32_list(body, "features")?;
            if features.len() != self.data.train.cols() {
                return Err(wire_err(&format!(
                    "query features have dim {}, model expects {}",
                    features.len(),
                    self.data.train.cols()
                )));
            }
            let label = match body.get("label") {
                Some(n) => Some(json_u64(n, "label")? as u32),
                None => None,
            };
            Ok(KnnQuery {
                features,
                label,
                seed: opt_seed(body, self.seed)?,
            })
        } else {
            let n_test = self.data.test.rows();
            if n_test == 0 {
                return Err(wire_err("no held-out test rows to address"));
            }
            let t = u64_field(body, "test_row")? as usize % n_test;
            Ok(KnnQuery {
                features: self.data.test.row(t).to_vec(),
                label: Some(self.data.test_labels[t]),
                seed: self.seed ^ t as u64,
            })
        }
    }

    fn response_to_json(&self, response: &u32) -> Json {
        Json::obj(vec![("label", (*response as usize).into())])
    }

    fn delta_from_json(&self, body: &Json) -> Result<LabeledPoint> {
        let features = f32_list(body, "features")?;
        if features.len() != self.data.train.cols() {
            return Err(wire_err(&format!(
                "delta features have dim {}, model expects {}",
                features.len(),
                self.data.train.cols()
            )));
        }
        let label = u64_field(body, "label")? as u32;
        Ok(LabeledPoint { features, label })
    }
}

/// CF codec. Queries: `{"test_row": T}` addresses a held-out (user,
/// item, rating) triplet and builds the user's centered row + mask the
/// same way [`super::query_log::cf_query_log`] does. Deltas:
/// `{"user": U}` — a train-matrix user row to fold into the shards
/// (matching [`CfModel`]'s `Delta = u32`).
#[derive(Clone)]
pub struct CfWire {
    /// Ratings split the row-indexed form addresses into.
    pub split: Arc<RatingsSplit>,
    /// Base seed folded into per-query plan seeds.
    pub seed: u64,
}

impl WireCodec<CfModel> for CfWire {
    fn app(&self) -> &'static str {
        "cf"
    }

    fn query_from_json(&self, body: &Json) -> Result<CfQuery> {
        let n_test = self.split.test.len();
        if n_test == 0 {
            return Err(wire_err("no held-out ratings to address"));
        }
        let t = u64_field(body, "test_row")? as usize % n_test;
        let (u, item, actual) = self.split.test[t];
        let (cu, mean) = self.split.train.centered_row(u as usize);
        let mut mu = vec![0.0f32; self.split.train.n_items()];
        for &it in &self.split.train.rated[u as usize] {
            mu[it as usize] = 1.0;
        }
        Ok(CfQuery {
            cu: Arc::new(cu),
            mu: Arc::new(mu),
            mean,
            item,
            exclude: Some(u),
            actual: Some(actual),
            seed: self.seed ^ t as u64,
        })
    }

    fn response_to_json(&self, response: &f32) -> Json {
        Json::obj(vec![("rating", f64::from(*response).into())])
    }

    fn delta_from_json(&self, body: &Json) -> Result<u32> {
        let u = u64_field(body, "user")? as usize;
        if u >= self.split.train.n_users() {
            return Err(wire_err(&format!(
                "delta user {u} out of range (train has {})",
                self.split.train.n_users()
            )));
        }
        Ok(u as u32)
    }
}

/// k-means codec. Queries: `{"row": R}` (a training point, un-jittered
/// so repeats cache-hit) or `{"point": [...]}`. Deltas:
/// `{"point": [...]}` or `{"row": R}`.
#[derive(Clone)]
pub struct KmeansWire {
    /// Point set the row-indexed form addresses into.
    pub points: Arc<Matrix>,
    /// Base seed folded into per-query plan seeds.
    pub seed: u64,
}

impl KmeansWire {
    fn point_of(&self, body: &Json) -> Result<(Vec<f32>, u64)> {
        if body.get("point").is_some() {
            let point = f32_list(body, "point")?;
            if point.len() != self.points.cols() {
                return Err(wire_err(&format!(
                    "point has dim {}, model expects {}",
                    point.len(),
                    self.points.cols()
                )));
            }
            Ok((point, self.seed))
        } else {
            let rows = self.points.rows();
            if rows == 0 {
                return Err(wire_err("no points to address"));
            }
            let r = u64_field(body, "row")? as usize % rows;
            Ok((self.points.row(r).to_vec(), self.seed ^ r as u64))
        }
    }
}

impl WireCodec<KmeansModel> for KmeansWire {
    fn app(&self) -> &'static str {
        "kmeans"
    }

    fn query_from_json(&self, body: &Json) -> Result<KmeansQuery> {
        let (point, seed) = self.point_of(body)?;
        let seed = match body.get("seed") {
            Some(n) => json_u64(n, "seed")?,
            None => seed,
        };
        Ok(KmeansQuery { point, seed })
    }

    fn response_to_json(&self, response: &RepMatch) -> Json {
        Json::obj(vec![
            ("cluster", (response.cluster as usize).into()),
            ("dist", f64::from(response.dist).into()),
        ])
    }

    fn delta_from_json(&self, body: &Json) -> Result<Vec<f32>> {
        Ok(self.point_of(body)?.0)
    }
}

// ---- shared field helpers ------------------------------------------------

fn wire_err(msg: &str) -> Error {
    Error::Config(format!("wire: {msg}"))
}

fn body_map(body: &Json) -> BTreeMap<String, Json> {
    match body {
        Json::Obj(m) => m.clone(),
        other => {
            let mut m = BTreeMap::new();
            m.insert("body".to_string(), other.clone());
            m
        }
    }
}

fn take_type(m: &mut BTreeMap<String, Json>) -> Result<String> {
    match m.remove("type") {
        Some(Json::Str(s)) => Ok(s),
        Some(_) => Err(wire_err("type is not a string")),
        None => Err(wire_err("line has no type field")),
    }
}

fn take_u64(m: &mut BTreeMap<String, Json>, key: &str) -> Result<u64> {
    match m.remove(key) {
        Some(v) => json_u64(&v, key),
        None => Err(wire_err(&format!("missing {key}"))),
    }
}

fn json_u64(v: &Json, key: &str) -> Result<u64> {
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 1.8e19 => Ok(*n as u64),
        _ => Err(wire_err(&format!("{key} is not a non-negative integer"))),
    }
}

fn u64_field(v: &Json, key: &str) -> Result<u64> {
    match v.get(key) {
        Some(n) => json_u64(n, key),
        None => Err(wire_err(&format!("missing {key}"))),
    }
}

fn bool_field(v: &Json, key: &str) -> Result<bool> {
    match v.get(key) {
        Some(Json::Bool(b)) => Ok(*b),
        Some(_) => Err(wire_err(&format!("{key} is not a bool"))),
        None => Err(wire_err(&format!("missing {key}"))),
    }
}

fn f32_list(v: &Json, key: &str) -> Result<Vec<f32>> {
    v.arr_of(key)?
        .iter()
        .map(|x| x.as_num().map(|n| n as f32))
        .collect()
}

fn opt_seed(body: &Json, default: u64) -> Result<u64> {
    match body.get("seed") {
        Some(n) => json_u64(n, "seed"),
        None => Ok(default),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixtureSpec;
    use crate::data::ratings::LatentFactorSpec;
    use crate::serve::stats::ServeStage;

    fn roundtrip_request(r: Request) {
        let line = r.to_line();
        let back = Request::parse_line(&line).expect("request round-trip parses");
        assert_eq!(back, r, "line was {line}");
    }

    fn roundtrip_reply(r: Reply) {
        let line = r.to_line();
        let back = Reply::parse_line(&line).expect("reply round-trip parses");
        assert_eq!(back, r, "line was {line}");
    }

    #[test]
    fn every_request_type_survives_encode_decode() {
        roundtrip_request(Request::query(7, vec![("test_row", 42usize.into())]));
        roundtrip_request(Request::query(
            u64::from(u32::MAX),
            vec![("features", Json::nums(&[1.0, -2.5, 0.25])), ("label", 3usize.into())],
        ));
        roundtrip_request(Request::Ingest {
            body: Json::obj(vec![(
                "deltas",
                Json::Arr(vec![Json::obj(vec![("user", 9usize.into())])]),
            )]),
        });
        roundtrip_request(Request::Stats);
        roundtrip_request(Request::Metrics);
        roundtrip_request(Request::Shutdown);
    }

    #[test]
    fn every_reply_type_survives_encode_decode() {
        roundtrip_reply(Reply::Response {
            id: 3,
            generation: 2,
            cache_hit: false,
            during_rebuild: true,
            queue_ms: 0.125,
            initial_ms: 1.5,
            total_ms: 4.75,
            initial: Json::obj(vec![("label", 1usize.into())]),
            refined: Some(Json::obj(vec![("label", 2usize.into())])),
            trace: trace_json(&[
                ServeTracePoint {
                    stage: ServeStage::Initial,
                    wall_s: 0.0015,
                    accuracy: Some(0.0),
                    refined_buckets: 0,
                },
                ServeTracePoint {
                    stage: ServeStage::Refined,
                    wall_s: 0.00475,
                    accuracy: Some(1.0),
                    refined_buckets: 4,
                },
            ]),
        });
        roundtrip_reply(Reply::Response {
            id: 0,
            generation: 0,
            cache_hit: true,
            during_rebuild: false,
            queue_ms: 0.0,
            initial_ms: 0.0,
            total_ms: 0.0,
            initial: Json::obj(vec![("rating", 3.5.into())]),
            refined: None,
            trace: Json::Arr(Vec::new()),
        });
        roundtrip_reply(Reply::Ingested {
            accepted: 12,
            generation: 1,
        });
        roundtrip_reply(Reply::Stats {
            body: Json::obj(vec![("queries", 10usize.into()), ("p99_s", 0.004.into())]),
        });
        roundtrip_reply(Reply::Metrics {
            body: Json::obj(vec![(
                "counters",
                Json::obj(vec![("aml_queries_total", 3usize.into())]),
            )]),
        });
        roundtrip_reply(Reply::Shutdown { served: 1234 });
        roundtrip_reply(Reply::Error {
            id: Some(5),
            message: "bad \"body\"".to_string(),
        });
        roundtrip_reply(Reply::Error {
            id: None,
            message: "unparseable line".to_string(),
        });
    }

    #[test]
    fn malformed_lines_yield_errors_not_panics() {
        for line in [
            "",
            "not json",
            "[1,2,3]",
            "{\"id\":1}",
            "{\"type\":\"nope\"}",
            "{\"type\":\"query\"}",
            "{\"type\":\"query\",\"id\":-3}",
            "{\"type\":\"query\",\"id\":1.5}",
            "{\"type\":3}",
            "{\"type\":\"response\",\"id\":1}",
        ] {
            assert!(
                Request::parse_line(line).is_err() || Reply::parse_line(line).is_err(),
                "line {line:?} should fail at least one direction"
            );
        }
        assert!(Request::parse_line("{\"type\":\"nope\"}").is_err());
        assert!(Reply::parse_line("{\"type\":\"nope\"}").is_err());
        assert!(Reply::parse_line("{\"type\":\"response\"}").is_err());
    }

    fn knn_wire() -> KnnWire {
        let data = GaussianMixtureSpec {
            n_points: 200,
            dim: 4,
            n_classes: 2,
            test_fraction: 0.1,
            ..Default::default()
        }
        .generate()
        .unwrap();
        KnnWire {
            data: Arc::new(data),
            seed: 7,
        }
    }

    #[test]
    fn knn_codec_addresses_rows_and_decodes_explicit_features() {
        let w = knn_wire();
        let n_test = w.data.test.rows();
        let q = w
            .query_from_json(&Json::obj(vec![("test_row", 3usize.into())]))
            .unwrap();
        assert_eq!(q.features, w.data.test.row(3 % n_test).to_vec());
        assert!(q.label.is_some());
        // Row addressing cycles like the replay query log, so hot keys
        // repeat exactly (same bytes => same cache key).
        let q2 = w
            .query_from_json(&Json::obj(vec![("test_row", (3 + n_test).into())]))
            .unwrap();
        assert_eq!(q.features, q2.features);
        assert_eq!(q.seed, q2.seed);

        let explicit = w
            .query_from_json(&Json::obj(vec![(
                "features",
                Json::nums(&[0.0, 1.0, 2.0, 3.0]),
            )]))
            .unwrap();
        assert_eq!(explicit.features, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(explicit.label, None);

        assert!(w
            .query_from_json(&Json::obj(vec![("features", Json::nums(&[1.0]))]))
            .is_err());
        assert!(w.query_from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn knn_codec_decodes_and_validates_deltas() {
        let w = knn_wire();
        let d = w
            .delta_from_json(&Json::obj(vec![
                ("features", Json::nums(&[1.0, 2.0, 3.0, 4.0])),
                ("label", 1usize.into()),
            ]))
            .unwrap();
        assert_eq!(d.label, 1);
        assert_eq!(d.features.len(), 4);
        assert!(w
            .delta_from_json(&Json::obj(vec![
                ("features", Json::nums(&[1.0])),
                ("label", 1usize.into()),
            ]))
            .is_err());
    }

    #[test]
    fn cf_codec_builds_centered_rows_and_validates_delta_users() {
        let m = LatentFactorSpec {
            n_users: 60,
            n_items: 24,
            mean_ratings_per_user: 8,
            ..Default::default()
        }
        .generate()
        .unwrap();
        let split = RatingsSplit::new(&m, 4, 0.2, 3).unwrap();
        let w = CfWire {
            split: Arc::new(split),
            seed: 11,
        };
        let q = w
            .query_from_json(&Json::obj(vec![("test_row", 0usize.into())]))
            .unwrap();
        let (u, item, actual) = w.split.test[0];
        assert_eq!(q.item, item);
        assert_eq!(q.exclude, Some(u));
        assert_eq!(q.actual, Some(actual));
        assert_eq!(q.mu.len(), w.split.train.n_items());

        assert_eq!(
            w.delta_from_json(&Json::obj(vec![("user", 1usize.into())]))
                .unwrap(),
            1
        );
        assert!(w
            .delta_from_json(&Json::obj(vec![("user", 10_000usize.into())]))
            .is_err());
    }

    #[test]
    fn kmeans_codec_addresses_rows_and_points() {
        let pts = Matrix::from_vec(4, 2, vec![0., 0., 1., 1., 2., 2., 3., 3.]).unwrap();
        let w = KmeansWire {
            points: Arc::new(pts),
            seed: 5,
        };
        let q = w
            .query_from_json(&Json::obj(vec![("row", 2usize.into())]))
            .unwrap();
        assert_eq!(q.point, vec![2.0, 2.0]);
        let q2 = w
            .query_from_json(&Json::obj(vec![("point", Json::nums(&[0.5, 0.5]))]))
            .unwrap();
        assert_eq!(q2.point, vec![0.5, 0.5]);
        assert!(w
            .query_from_json(&Json::obj(vec![("point", Json::nums(&[0.5]))]))
            .is_err());
        let d = w
            .delta_from_json(&Json::obj(vec![("row", 1usize.into())]))
            .unwrap();
        assert_eq!(d, vec![1.0, 1.0]);
    }
}
