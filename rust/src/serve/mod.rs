//! The sharded anytime serving subsystem.
//!
//! The paper's two-stage split — *initial answer from aggregated
//! points, refinement from accuracy-critical originals* — maps directly
//! onto deadline-bounded anytime query serving (the contract EARL-style
//! systems expose to clients): every request always gets its initial
//! answer, and whatever per-request budget remains is spent refining
//! the Algorithm-1-ranked buckets.
//!
//! Pieces:
//!
//! * [`AnswerCache`] — the hot-query answer cache sitting in front of
//!   admission: repeat queries (keyed on their answer-relevant bytes)
//!   are served their cached final response at zero compute; it can be
//!   held externally ([`SharedAnswerCache`] +
//!   [`ShardedServer::serve_with_cache`]) so repeat traffic across
//!   replay loops hits, with [`AnswerCache::invalidate_all`] as the
//!   model-swap lifecycle hook;
//! * [`MicroBatcher`] — groups in-flight requests so each model shard
//!   sees one task per batch instead of one task per query;
//! * [`ShardedServer`] — shards a [`crate::model::ServableModel`]
//!   across the engine's [`crate::util::pool::WorkerPool`], answers a
//!   whole micro-batch per shard in ONE backend call
//!   ([`crate::model::ServableModel::answer_initial_block`]), merges
//!   the per-shard answers into initial responses, then spends the
//!   remaining budget on stage-2 refinement — one
//!   [`crate::model::ServableModel::refine_block`] task per shard, the
//!   batch's bucket rescans grouped so queries refining the same
//!   bucket share one gathered block and ONE backend call per (shard,
//!   bucket-group) (same drain/failure path as the batch engine:
//!   [`crate::mapreduce::engine::drain_stream`]); the `Deadline`
//!   budget is calibrated by a per-shard EWMA of measured stage-1
//!   cost, and under queue pressure refinement is shed
//!   ([`ServeConfig::shed_queue_depth`]) before requests would be
//!   rejected;
//! * [`query_log`] — synthetic query logs derived from the workbench
//!   datasets, for replay by the CLI `serve` command, the e2e tests and
//!   `benches/serving.rs`;
//! * [`Session`] — the one serving surface over a built model set
//!   (registry + cache + validated config), driven by replay,
//!   refresh-replay, or daemon mode;
//! * [`protocol`] — the line-delimited JSONL wire protocol (`query`,
//!   `response`, `ingest`, `stats`, `shutdown` messages) plus the
//!   per-app [`WireCodec`]s that translate wire bodies to typed
//!   queries/deltas;
//! * [`Daemon`] — the long-running server: reader threads per client
//!   connection feed a single serving thread through an event queue,
//!   so micro-batching, shedding, deadline budgets and atomic swaps
//!   operate on real arrival times and live queue depth;
//! * [`loadgen`] — the open-loop timestamped load generator (Poisson
//!   and bursty arrivals, Zipf-skewed hot keys) that drives a daemon
//!   at a sweep of offered rates and reports qps-vs-tail-latency
//!   curves;
//! * live refresh — the server pins one
//!   [`crate::refresh::ModelRegistry`] generation per micro-batch at
//!   dispatch, so shard sets rebuilt in the background
//!   ([`crate::refresh::Rebuilder`]) can be hot-swapped between batches
//!   without tearing in-flight queries; the executor drives the
//!   machinery through a [`RefreshHook`]
//!   ([`ShardedServer::serve_with_refresh`], cycles every
//!   [`ServeConfig::refresh`]`.every` queries), and shedding reads the
//!   hook's *live* queue depth instead of the replay stand-in;
//! * [`ServeReport`] — per-run latency percentiles plus
//!   initial-vs-refined accuracy, cache hit counts, shed/bucket-group
//!   counters and the budget calibration state; each [`QueryOutcome`]
//!   additionally carries its own [`ServeTracePoint`] checkpoints, the
//!   per-request analogue of
//!   [`crate::mapreduce::metrics::TracePoint`] accounting.

pub mod batcher;
pub mod cache;
pub mod daemon;
pub mod executor;
pub mod loadgen;
pub mod protocol;
pub mod query_log;
pub mod session;
pub mod stats;

pub use batcher::MicroBatcher;
pub use cache::AnswerCache;
pub use daemon::{Daemon, DaemonReport};
pub use executor::{
    AdmittedQuery, QueryOutcome, RefineBudget, RefreshHook, RefreshPolicy, ServeConfig,
    ServeConfigBuilder, ServeCounters, ShardedServer, SharedAnswerCache,
};
pub use loadgen::{ArrivalProcess, LoadSpec, ScenarioResult};
pub use protocol::{CfWire, KmeansWire, KnnWire, Reply, Request, WireCodec};
pub use session::Session;
pub use stats::{
    ClassCurvePoint, ClassReport, LatencyStats, ServeReport, ServeStage, ServeTracePoint,
};
