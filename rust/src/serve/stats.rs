//! Serving-side accounting: latency percentiles and initial-vs-refined
//! accuracy — the per-request analogue of the batch engine's
//! [`crate::mapreduce::metrics::TracePoint`] trace.

use crate::util::table::{f, Table};

/// Latency summary over a set of per-request samples (seconds).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl LatencyStats {
    /// Summarize raw samples (empty input yields zeros).
    pub fn from_samples(mut samples: Vec<f64>) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        LatencyStats {
            n,
            mean_s: mean,
            p50_s: percentile(&samples, 0.50),
            p90_s: percentile(&samples, 0.90),
            p99_s: percentile(&samples, 0.99),
            max_s: samples[n - 1],
        }
    }
}

/// Nearest-rank percentile over an ascending-sorted sample.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
    sorted[idx]
}

/// Which response a per-request trace checkpoint describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ServeStage {
    /// The always-delivered aggregated-only answer (stage 1).
    Initial,
    /// The post-refinement answer (stage 2 ran on this request).
    Refined,
    /// A hot-query cache hit replaying a previously computed final
    /// response at zero compute.
    CacheHit,
}

impl ServeStage {
    /// Stable lowercase name (report tables, JSON artifacts).
    pub fn name(&self) -> &'static str {
        match self {
            ServeStage::Initial => "initial",
            ServeStage::Refined => "refined",
            ServeStage::CacheHit => "cache_hit",
        }
    }
}

/// One per-request anytime checkpoint — the serving analogue of the
/// batch trace's [`crate::mapreduce::metrics::TracePoint`]: when a
/// response became available and what it was worth. Each
/// [`crate::serve::QueryOutcome`] carries its checkpoints in order
/// (initial, then post-refinement when stage 2 ran), so anytime
/// curves can be plotted per query class by grouping outcomes.
#[derive(Clone, Copy, Debug)]
pub struct ServeTracePoint {
    /// Which response this checkpoint describes.
    pub stage: ServeStage,
    /// Seconds from batch dispatch to this response (0 on cache hits).
    pub wall_s: f64,
    /// Per-query accuracy at this checkpoint (ground truth
    /// permitting).
    pub accuracy: Option<f64>,
    /// Buckets expanded by this checkpoint, summed over shards.
    pub refined_buckets: usize,
}

/// One point of a per-class anytime curve: the mean availability time
/// and quality of one response stage across every query of the class
/// that reached it.
#[derive(Clone, Debug)]
pub struct ClassCurvePoint {
    /// Which response this point averages.
    pub stage: ServeStage,
    /// Queries of the class that produced this stage.
    pub queries: usize,
    /// Mean seconds from batch dispatch to this response.
    pub mean_wall_s: f64,
    /// Mean per-query accuracy at this stage (ground truth permitting).
    pub mean_accuracy: Option<f64>,
    /// Mean buckets expanded by this stage, summed over shards.
    pub mean_refined_buckets: f64,
}

/// Per-class serving summary: every query of one class
/// ([`crate::model::ServableModel::query_class`] — label for kNN,
/// user-activity band for CF, delivered cluster for k-means) with its
/// anytime curve, derived by averaging the per-request
/// [`ServeTracePoint`] checkpoints stage by stage.
#[derive(Clone, Debug)]
pub struct ClassReport {
    /// The class tag.
    pub class: String,
    /// Queries grouped under this class.
    pub queries: usize,
    /// Of those, answered from the hot-query cache.
    pub cache_hits: usize,
    /// The class's anytime curve, one point per stage reached (initial,
    /// then refined, then cache-hit replays), in stage order.
    pub curve: Vec<ClassCurvePoint>,
}

/// One serving run's report: how fast the initial answers landed, how
/// fast the refined ones did, and what each was worth.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Requests replayed.
    pub queries: usize,
    /// Model shards served from.
    pub shards: usize,
    /// Latency of the always-delivered initial answer.
    pub initial: LatencyStats,
    /// End-to-end latency including refinement (== initial when no
    /// budget was spent).
    pub total: LatencyStats,
    /// Mean per-query accuracy of initial answers, over queries whose
    /// stage 1 actually ran — cache hits replay a final response and
    /// are excluded (None when no such query carried ground truth).
    /// Metric is app-defined: kNN 0/1 correctness, CF negative squared
    /// rating error, k-means negative squared distance to the chosen
    /// representative.
    pub initial_accuracy: Option<f64>,
    /// Mean per-query accuracy of the final (client-visible) response:
    /// the refined answer where refinement ran, the cached final
    /// response for cache hits, the initial answer otherwise —
    /// averaged over every ground-truth query so partial refinement
    /// cannot bias the comparison by averaging an easier subset.
    pub refined_accuracy: Option<f64>,
    /// Requests that received any refinement.
    pub refined_queries: usize,
    /// Mean buckets expanded per refined request (summed over shards).
    pub refined_buckets_mean: f64,
    /// Requests whose initial answer landed after their deadline.
    pub deadline_misses: usize,
    /// Micro-batches whose refinement was shed (downgraded to
    /// initial-only) because more than
    /// [`crate::serve::ServeConfig::shed_queue_depth`] batches were
    /// pending behind them.
    pub shed_batches: usize,
    /// Stage-2 bucket-groups scored across the replay: distinct
    /// (shard, bucket) pairs expanded per batch, each gathered and
    /// scored in ONE backend call however many queries shared it. 0
    /// when no refinement ran (or the model uses the per-query default
    /// path).
    pub stage2_bucket_groups: usize,
    /// Hot-query answer-cache hits (requests served at zero compute).
    pub cache_hits: usize,
    /// Answer-cache lookups (cacheable requests seen while the cache
    /// was enabled; 0 when it was off).
    pub cache_lookups: usize,
    /// Per-shard EWMA of the measured stage-1 cost per (query ×
    /// bucket), seconds — the [`crate::serve::RefineBudget::Deadline`]
    /// calibration state after the replay (0.0 = shard never measured).
    pub stage1_bucket_cost_ewma_s: Vec<f64>,
    /// Atomic shard-set swaps published during this replay (0 when no
    /// refresh hook was attached or no rebuild completed).
    pub refresh_swap_count: usize,
    /// The registry generation after the replay (0 = the initial
    /// build; counts every publish over the registry's lifetime, so it
    /// can exceed `refresh_swap_count` when the registry served earlier
    /// replays).
    pub refresh_generation: u64,
    /// Queries dispatched while a background shard rebuild was in
    /// flight — answered from a generation known to be missing
    /// already-ingested data (the refresh staleness counter; 0 without
    /// a refresh hook).
    pub stale_queries: usize,
    /// Total-latency stats over exactly those stale queries: what
    /// serving cost while rebuilds were competing for the worker pool
    /// (`during_rebuild.p99_s` is the bench's
    /// `serve_during_rebuild_p99_s`). Zeros when no query was served
    /// during a rebuild.
    pub during_rebuild: LatencyStats,
    /// Per-class anytime curves (classes defined by
    /// [`crate::model::ServableModel::query_class`]; empty when the
    /// model classifies nothing), sorted by class tag.
    pub per_class: Vec<ClassReport>,
}

impl ServeReport {
    /// Fraction of cache lookups that hit (0 when none were made).
    pub fn cache_hit_rate(&self) -> f64 {
        if self.cache_lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.cache_lookups as f64
        }
    }
    /// Render as a two-row latency table (initial vs refined) plus an
    /// accuracy row.
    pub fn table(&self, title: &str) -> Table {
        let ms = |s: f64| f(s * 1e3, 3);
        let mut t = Table::new(
            title,
            &["answer", "p50_ms", "p90_ms", "p99_ms", "max_ms", "mean_accuracy"],
        );
        t.row(vec![
            "initial".into(),
            ms(self.initial.p50_s),
            ms(self.initial.p90_s),
            ms(self.initial.p99_s),
            ms(self.initial.max_s),
            self.initial_accuracy.map(|a| f(a, 4)).unwrap_or_else(|| "-".into()),
        ]);
        t.row(vec![
            "refined".into(),
            ms(self.total.p50_s),
            ms(self.total.p90_s),
            ms(self.total.p99_s),
            ms(self.total.max_s),
            self.refined_accuracy.map(|a| f(a, 4)).unwrap_or_else(|| "-".into()),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_on_known_samples() {
        let s = LatencyStats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.n, 100);
        assert!((s.p50_s - 50.0).abs() <= 1.0);
        assert!((s.p99_s - 99.0).abs() <= 1.0);
        assert_eq!(s.max_s, 100.0);
        assert!((s.mean_s - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_samples_are_zero() {
        let s = LatencyStats::from_samples(vec![]);
        assert_eq!(s.n, 0);
        assert_eq!(s.max_s, 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
