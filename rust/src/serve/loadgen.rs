//! Open-loop timestamped load generation against a [`Daemon`].
//!
//! The generator draws an arrival schedule *up front* — Poisson or
//! sinusoidally-modulated ("bursty") inter-arrival gaps at a target
//! offered rate, with Zipf-skewed key popularity — then a client
//! thread paces sends against that schedule over a real TCP connection
//! while the daemon serves on the calling thread. Each response's
//! latency is measured from its **scheduled** arrival time, not from
//! when the send actually went out: a server that falls behind delays
//! subsequent sends in a closed-loop harness and hides its own
//! queueing, whereas here the backlog lands in the latency numbers
//! (the coordinated-omission correction open-loop benchmarks exist
//! for).
//!
//! [`run_scenario`] runs one (arrival process, offered rate) cell and
//! returns a [`ScenarioResult`]; [`run_sweep`] maps a rate list
//! through it to produce a qps-vs-tail-latency curve.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::mapreduce::engine::Engine;
use crate::refresh::Refreshable;
use crate::serve::daemon::Daemon;
use crate::serve::protocol::{Reply, Request, WireCodec};
use crate::serve::session::Session;
use crate::serve::stats::percentile;
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};

/// The inter-arrival process offered to the daemon.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential gaps at the offered rate.
    Poisson,
    /// Sinusoidally modulated rate: `offered * (1 + amplitude *
    /// sin(2π t / period_s))`, floored at 5% of the offered rate. An
    /// `amplitude` near 1 alternates quiet valleys with bursts at
    /// roughly twice the offered rate — the regime that exercises
    /// shedding and partial-batch timeouts.
    Bursty {
        /// Seconds per modulation cycle.
        period_s: f64,
        /// Fractional swing around the offered rate, clamped to [0, 1].
        amplitude: f64,
    },
}

impl ArrivalProcess {
    /// Stable name for reports ("poisson" / "bursty").
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
        }
    }

    /// Instantaneous rate at time `t` for a target offered rate.
    fn rate_at(&self, offered_qps: f64, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson => offered_qps,
            ArrivalProcess::Bursty {
                period_s,
                amplitude,
            } => {
                let a = amplitude.clamp(0.0, 1.0);
                let phase = 2.0 * std::f64::consts::PI * t / period_s.max(1e-6);
                (offered_qps * (1.0 + a * phase.sin())).max(offered_qps * 0.05)
            }
        }
    }
}

/// One load-generation cell: how many queries, at what offered rate,
/// over how skewed a key population.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    /// Target average arrival rate (queries per second).
    pub offered_qps: f64,
    /// Total queries in the schedule.
    pub n_queries: usize,
    /// Distinct query keys (rows) the Zipf draw ranges over.
    pub users: usize,
    /// Zipf exponent for key popularity (0 = uniform; ~1 = web-like
    /// skew that gives the answer cache real hits).
    pub zipf_s: f64,
    /// Schedule seed: same spec + seed = same schedule, bit-for-bit.
    pub seed: u64,
    /// Arrival process.
    pub arrival: ArrivalProcess,
}

/// One scheduled arrival: when, and for which key.
#[derive(Clone, Copy, Debug)]
pub struct ArrivalEvent {
    /// Scheduled arrival time, seconds from scenario start.
    pub at_s: f64,
    /// Zipf-ranked key index in `[0, users)`.
    pub user: usize,
}

/// Measured outcome of one scenario cell, flattened for the bench
/// artifact.
#[derive(Clone, Copy, Debug)]
pub struct ScenarioResult {
    /// Arrival process name ("poisson" / "bursty").
    pub arrival: &'static str,
    /// The rate the schedule targeted.
    pub offered_qps: f64,
    /// Responses delivered per second of scenario wall time.
    pub achieved_qps: f64,
    /// Responses received.
    pub queries: usize,
    /// Median delivered latency, measured from scheduled arrival.
    pub p50_s: f64,
    /// 99th-percentile delivered latency.
    pub p99_s: f64,
    /// Micro-batches the daemon downgraded to initial-only.
    pub shed_batches: usize,
    /// Answer-cache hits during the scenario.
    pub cache_hits: usize,
    /// Answer-cache lookups during the scenario.
    pub cache_lookups: usize,
    /// Shard-set hot-swaps published during the scenario.
    pub swaps: usize,
    /// Registry generation when the daemon exited.
    pub generation: u64,
    /// Total error replies plus unparseable lines (should be 0).
    pub errors: usize,
    /// Well-formed `error` replies from the daemon (wire errors).
    pub error_wire: usize,
    /// Reply lines the client could not parse at all.
    pub error_parse: usize,
}

impl ScenarioResult {
    /// Flatten into the object `BENCH_serving.json` embeds per cell.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arrival", self.arrival.into()),
            ("offered_qps", self.offered_qps.into()),
            ("achieved_qps", self.achieved_qps.into()),
            ("queries", self.queries.into()),
            ("p50_s", self.p50_s.into()),
            ("p99_s", self.p99_s.into()),
            ("shed_batches", self.shed_batches.into()),
            ("cache_hits", self.cache_hits.into()),
            ("cache_lookups", self.cache_lookups.into()),
            ("swaps", self.swaps.into()),
            ("generation", Json::Num(self.generation as f64)),
            ("errors", self.errors.into()),
            (
                "error_kinds",
                Json::obj(vec![
                    ("wire", self.error_wire.into()),
                    ("parse", self.error_parse.into()),
                ]),
            ),
        ])
    }
}

/// Draw the full arrival schedule for a spec. Deterministic in the
/// seed; timestamps are strictly non-decreasing.
pub fn schedule(spec: &LoadSpec) -> Vec<ArrivalEvent> {
    assert!(spec.offered_qps > 0.0, "offered rate must be positive");
    assert!(spec.users > 0, "need at least one user key");
    let mut rng = Rng::new(spec.seed);
    let zipf = Zipf::new(spec.users, spec.zipf_s.max(0.0));
    let mut events = Vec::with_capacity(spec.n_queries);
    let mut t = 0.0f64;
    for _ in 0..spec.n_queries {
        let rate = spec.arrival.rate_at(spec.offered_qps, t);
        // Inverse-CDF exponential gap; (1 - u) keeps ln's argument in
        // (0, 1] since u is drawn from [0, 1).
        let gap = -(1.0 - rng.f64()).ln() / rate;
        t += gap;
        events.push(ArrivalEvent {
            at_s: t,
            user: zipf.sample(&mut rng),
        });
    }
    events
}

/// Run one scenario cell: serve a [`Daemon`] on this thread while a
/// client thread paces the spec's schedule at it over TCP, keyed by
/// `key_field` (`"test_row"` for knn/cf logs, `"row"` for k-means).
///
/// The session's answer cache is invalidated first so each cell starts
/// cold — warmth inherited from a previous (lower-rate) cell would
/// make tail-latency curves incomparable across rates.
pub fn run_scenario<M: Refreshable, C: WireCodec<M>>(
    engine: &Engine,
    session: &Session<M>,
    codec: Arc<C>,
    spec: &LoadSpec,
    key_field: &'static str,
) -> Result<ScenarioResult> {
    session.cache().lock().unwrap().invalidate_all();
    let listener = TcpListener::bind(("127.0.0.1", 0)).map_err(Error::Io)?;
    let addr = listener.local_addr().map_err(Error::Io)?;
    let events = schedule(spec);

    let client = thread::spawn(move || -> std::io::Result<(Vec<f64>, usize, usize, f64)> {
        // The bound listener's backlog holds this connection until the
        // daemon's accept loop starts.
        let stream = TcpStream::connect(addr)?;
        let send_half = stream.try_clone()?;
        let scheduled: Vec<f64> = events.iter().map(|e| e.at_s).collect();
        let epoch = Instant::now();
        let sender = thread::spawn(move || {
            let mut w = send_half;
            for (i, ev) in events.iter().enumerate() {
                sleep_until(epoch, ev.at_s);
                let req = Request::query(i as u64, vec![(key_field, ev.user.into())]);
                if writeln!(w, "{}", req.to_line()).is_err() {
                    return;
                }
            }
            // Same-connection FIFO: the daemon answers every query
            // above before acking this.
            let _ = writeln!(w, "{}", Request::Shutdown.to_line());
            let _ = w.flush();
        });
        let mut latencies = Vec::with_capacity(scheduled.len());
        let mut wire_errors = 0usize;
        let mut parse_errors = 0usize;
        let mut makespan = 0.0f64;
        for line in BufReader::new(stream).lines() {
            let line = line?;
            match Reply::parse_line(&line) {
                Ok(Reply::Response { id, .. }) => {
                    let now = epoch.elapsed().as_secs_f64();
                    if let Some(&at) = scheduled.get(id as usize) {
                        latencies.push((now - at).max(0.0));
                    }
                    makespan = now;
                }
                Ok(Reply::Shutdown { .. }) => {
                    makespan = makespan.max(epoch.elapsed().as_secs_f64());
                    break;
                }
                Ok(Reply::Error { .. }) => wire_errors += 1,
                Err(_) => parse_errors += 1,
                Ok(_) => {}
            }
        }
        let _ = sender.join();
        Ok((latencies, wire_errors, parse_errors, makespan))
    });

    let report = Daemon::new(session, codec).run_listener(engine, listener)?;
    let (mut latencies, error_wire, error_parse, makespan) = client
        .join()
        .map_err(|_| Error::Engine("load-generation client thread panicked".into()))?
        .map_err(Error::Io)?;
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    Ok(ScenarioResult {
        arrival: spec.arrival.name(),
        offered_qps: spec.offered_qps,
        achieved_qps: if makespan > 0.0 {
            latencies.len() as f64 / makespan
        } else {
            0.0
        },
        queries: latencies.len(),
        p50_s: percentile(&latencies, 0.50),
        p99_s: percentile(&latencies, 0.99),
        shed_batches: report.shed_batches,
        cache_hits: report.cache_hits,
        cache_lookups: report.cache_lookups,
        swaps: report.swaps,
        generation: report.generation,
        errors: error_wire + error_parse,
        error_wire,
        error_parse,
    })
}

/// Sweep one spec across `rates`, producing the qps-vs-latency curve
/// the bench artifact plots. Each cell reuses the session (models stay
/// warm) but starts with a cold answer cache.
pub fn run_sweep<M: Refreshable, C: WireCodec<M>>(
    engine: &Engine,
    session: &Session<M>,
    codec: &Arc<C>,
    base: &LoadSpec,
    rates: &[f64],
    key_field: &'static str,
) -> Result<Vec<ScenarioResult>> {
    rates
        .iter()
        .map(|&offered_qps| {
            let spec = LoadSpec {
                offered_qps,
                ..*base
            };
            run_scenario(engine, session, Arc::clone(codec), &spec, key_field)
        })
        .collect()
}

/// Sleep until `at_s` on `epoch`'s clock: coarse sleep to within half
/// a millisecond, then spin — OS sleep alone overshoots by more than a
/// typical inter-arrival gap at high offered rates.
fn sleep_until(epoch: Instant, at_s: f64) {
    loop {
        let remain = at_s - epoch.elapsed().as_secs_f64();
        if remain <= 0.0 {
            return;
        }
        if remain > 1e-3 {
            thread::sleep(Duration::from_secs_f64(remain - 5e-4));
        } else {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(arrival: ArrivalProcess) -> LoadSpec {
        LoadSpec {
            offered_qps: 200.0,
            n_queries: 4000,
            users: 64,
            zipf_s: 1.1,
            seed: 7,
            arrival,
        }
    }

    #[test]
    fn poisson_schedule_hits_the_offered_rate() {
        let s = spec(ArrivalProcess::Poisson);
        let events = schedule(&s);
        assert_eq!(events.len(), s.n_queries);
        let span = events.last().unwrap().at_s;
        let achieved = s.n_queries as f64 / span;
        // 4000 exponential gaps: the mean rate concentrates tightly.
        assert!(
            (achieved - s.offered_qps).abs() < s.offered_qps * 0.1,
            "achieved {achieved} vs offered {}",
            s.offered_qps
        );
    }

    #[test]
    fn schedules_are_deterministic_and_monotone() {
        let s = spec(ArrivalProcess::Poisson);
        let a = schedule(&s);
        let b = schedule(&s);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
            assert_eq!(x.user, y.user);
        }
        for w in a.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
    }

    #[test]
    fn zipf_keys_are_head_heavy() {
        let s = spec(ArrivalProcess::Poisson);
        let events = schedule(&s);
        let mut counts = vec![0usize; s.users];
        for e in &events {
            counts[e.user] += 1;
        }
        assert!(
            counts[0] > counts[s.users - 1] * 5,
            "head {} vs tail {}",
            counts[0],
            counts[s.users - 1]
        );
    }

    #[test]
    fn bursty_schedule_modulates_the_gap_distribution() {
        let bursty = schedule(&spec(ArrivalProcess::Bursty {
            period_s: 2.0,
            amplitude: 0.9,
        }));
        let gaps: Vec<f64> = bursty.windows(2).map(|w| w[1].at_s - w[0].at_s).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        // Rate modulation overdisperses gaps relative to exponential
        // (whose coefficient of variation is exactly 1).
        let cv2 = var / (mean * mean);
        assert!(cv2 > 1.1, "squared CV {cv2} not overdispersed");
        for w in bursty.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
    }

    #[test]
    fn scenario_result_flattens_to_the_artifact_keys() {
        let r = ScenarioResult {
            arrival: "poisson",
            offered_qps: 100.0,
            achieved_qps: 98.5,
            queries: 400,
            p50_s: 0.002,
            p99_s: 0.011,
            shed_batches: 3,
            cache_hits: 120,
            cache_lookups: 400,
            swaps: 1,
            generation: 1,
            errors: 2,
            error_wire: 1,
            error_parse: 1,
        };
        let j = r.to_json();
        for key in [
            "arrival",
            "offered_qps",
            "achieved_qps",
            "queries",
            "p50_s",
            "p99_s",
            "shed_batches",
            "cache_hits",
            "cache_lookups",
            "swaps",
            "generation",
            "errors",
            "error_kinds",
        ] {
            assert!(j.get(key).is_some(), "missing key {key}");
        }
        assert_eq!(j.num_of("p99_s").unwrap(), 0.011);
        assert_eq!(j.str_of("arrival").unwrap(), "poisson");
        let kinds = j.get("error_kinds").unwrap();
        assert_eq!(kinds.num_of("wire").unwrap(), 1.0);
        assert_eq!(kinds.num_of("parse").unwrap(), 1.0);
    }
}
