//! The long-running JSONL serving daemon.
//!
//! One [`Daemon`] drives a [`Session`] from *live* traffic instead of
//! a replay log. The threading contract:
//!
//! * **Reader threads** — one per client connection (plus one for
//!   stdin in [`Daemon::run_stdio`]) — parse each line into a
//!   [`Request`], decode query/delta bodies with the shared
//!   [`WireCodec`], and forward typed events into one mpsc channel.
//!   Malformed lines become events too, so every byte written to a
//!   client comes from the serving thread.
//! * **The serving thread** pops events, probes the answer cache at
//!   admission, micro-batches the misses, dispatches through
//!   [`ShardedServer::serve_admitted`], ingests deltas, and publishes
//!   finished rebuilds. Publishing on this thread keeps the swap +
//!   cache-invalidation step atomic with respect to cache inserts
//!   (the invariant [`ShardedServer::with_registry`] documents).
//!
//! Because arrivals are real, the machinery built for replays now
//! operates on real signals: each request's queue wait (event-queue
//! time + batcher time) is folded into its reported latencies, the
//! shedding policy reads the live event-queue depth, and a partial
//! batch is flushed by time ([`Daemon`] normalizes a time trigger when
//! the config releases on size only — a daemon must not hold a partial
//! batch hostage waiting for traffic that may never come).
//!
//! Shutdown semantics: on a `shutdown` request the daemon stops
//! admitting, drains every event already queued (same-connection FIFO
//! guarantees a client's earlier queries are all answered before its
//! ack), flushes the partial batch, lets in-flight rebuilds land, then
//! acks with `{"type":"shutdown","served":N}` and exits.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::mapreduce::engine::Engine;
use crate::refresh::{DeltaLog, Rebuilder, Refreshable};
use crate::serve::batcher::MicroBatcher;
use crate::serve::executor::{AdmittedQuery, ServeConfig, ServeCounters};
use crate::serve::protocol::{response_reply, Reply, Request, WireCodec};
use crate::serve::session::Session;
use crate::serve::stats::percentile;
use crate::util::json::Json;
use crate::util::timer::Stopwatch;

#[cfg(doc)]
use crate::serve::executor::ShardedServer;

/// Per-connection write halves, keyed by connection id. Registered by
/// the transport, removed when a reader sees EOF; only the serving
/// thread writes through them.
type Writers = Arc<Mutex<HashMap<usize, Arc<Mutex<Box<dyn Write + Send>>>>>>;

/// Recent delivered latencies kept for `stats` percentiles.
const LATENCY_WINDOW: usize = 4096;

/// One typed event from a reader thread to the serving thread.
enum Event<Q, D> {
    /// An admitted (well-formed) query; `queued_at` starts the queue
    /// wait clock at arrival.
    Query {
        conn: usize,
        id: u64,
        query: Arc<Q>,
        queued_at: Stopwatch,
    },
    /// Decoded `ingest` deltas.
    Ingest { conn: usize, deltas: Vec<D> },
    /// A `stats` request.
    Stats { conn: usize },
    /// A `metrics` request (observability registry snapshot).
    Metrics { conn: usize },
    /// A `shutdown` request; begins the graceful drain.
    Shutdown { conn: usize },
    /// A line that failed to parse or decode; answered with an `error`
    /// reply from the serving thread.
    BadLine {
        conn: usize,
        id: Option<u64>,
        message: String,
    },
    /// The connection's reader saw EOF; unregister its writer.
    Gone { conn: usize },
}

/// A cache-missed query waiting in the micro-batcher.
struct PendingReq<Q> {
    conn: usize,
    id: u64,
    query: Arc<Q>,
    key: Option<Vec<u8>>,
    queued_at: Stopwatch,
}

/// Counters over one daemon run (deltas against the session's
/// lifetime cache/registry totals, so repeated runs over one session
/// report per-run numbers).
#[derive(Clone, Copy, Debug, Default)]
pub struct DaemonReport {
    /// Queries answered (including cache hits).
    pub served: u64,
    /// Deltas accepted into the log via `ingest`.
    pub ingested: usize,
    /// Micro-batches downgraded to initial-only under queue pressure.
    pub shed_batches: usize,
    /// Answer-cache hits during this run.
    pub cache_hits: usize,
    /// Answer-cache lookups during this run.
    pub cache_lookups: usize,
    /// Atomic shard swaps published during this run.
    pub swaps: usize,
    /// Registry generation at exit.
    pub generation: u64,
}

/// Per-connection accounting surfaced by the `stats` reply.
#[derive(Clone, Copy, Debug, Default)]
struct ConnCounters {
    /// Well-formed queries received on this connection.
    queries: u64,
    /// Error replies written to this connection.
    errors: u64,
    /// Reply bytes written to this connection (including newlines).
    bytes: u64,
}

/// Mutable serving-loop state, bundled so the event handlers can
/// borrow pieces of it disjointly.
struct LoopState<M: Refreshable> {
    batcher: MicroBatcher<PendingReq<M::Query>>,
    counters: ServeCounters,
    window: VecDeque<f64>,
    served: u64,
    ingested: usize,
    log: Arc<DeltaLog<M::Delta>>,
    rebuilder: Rebuilder<M>,
    conns: HashMap<usize, ConnCounters>,
}

/// The long-running JSONL server over a [`Session`]; see the module
/// docs for the threading and shutdown contracts.
pub struct Daemon<'a, M: Refreshable, C: WireCodec<M>> {
    session: &'a Session<M>,
    codec: Arc<C>,
}

impl<'a, M: Refreshable, C: WireCodec<M>> Daemon<'a, M, C> {
    /// A daemon serving `session` with `codec` translating wire bodies.
    pub fn new(session: &'a Session<M>, codec: Arc<C>) -> Daemon<'a, M, C> {
        Daemon { session, codec }
    }

    /// The effective time trigger for partial batches: the configured
    /// wait when set, else a quarter of the deadline clamped to
    /// [0.5ms, 10ms] — a daemon with a size-only batcher would starve
    /// partial batches under sparse traffic.
    fn batch_wait_s(config: &ServeConfig) -> f64 {
        if config.max_batch_wait_s > 0.0 {
            config.max_batch_wait_s
        } else {
            (config.deadline_s / 4.0).clamp(0.0005, 0.01)
        }
    }

    /// Serve over TCP on `127.0.0.1:port` until a client sends
    /// `shutdown`.
    pub fn run_tcp(&self, engine: &Engine, port: u16) -> Result<DaemonReport> {
        let listener = TcpListener::bind(("127.0.0.1", port)).map_err(Error::Io)?;
        self.run_listener(engine, listener)
    }

    /// Serve over an already-bound listener (tests and the load
    /// generator bind an ephemeral port themselves). Accepts
    /// connections on a helper thread; each connection gets a dedicated
    /// reader thread. Returns after the graceful shutdown drain.
    pub fn run_listener(&self, engine: &Engine, listener: TcpListener) -> Result<DaemonReport> {
        listener.set_nonblocking(true).map_err(Error::Io)?;
        let (tx, rx) = mpsc::channel::<Event<M::Query, M::Delta>>();
        let queued = Arc::new(AtomicUsize::new(0));
        let writers: Writers = Arc::new(Mutex::new(HashMap::new()));
        let running = Arc::new(AtomicBool::new(true));

        let accept = {
            let tx = tx.clone();
            let queued = Arc::clone(&queued);
            let writers = Arc::clone(&writers);
            let running = Arc::clone(&running);
            let codec = Arc::clone(&self.codec);
            thread::spawn(move || {
                let mut next_conn = 1usize;
                while running.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let Ok(write_half) = stream.try_clone() else {
                                continue;
                            };
                            let conn = next_conn;
                            next_conn += 1;
                            writers.lock().unwrap().insert(
                                conn,
                                Arc::new(Mutex::new(Box::new(write_half) as Box<dyn Write + Send>)),
                            );
                            spawn_reader::<M, C>(
                                conn,
                                Box::new(stream),
                                Arc::clone(&codec),
                                tx.clone(),
                                Arc::clone(&queued),
                                false,
                            );
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
        };
        drop(tx);
        let report = self.serve_events(engine, rx, &queued, &writers);
        running.store(false, Ordering::SeqCst);
        let _ = accept.join();
        report
    }

    /// Serve one implicit connection over stdin/stdout (conn id 0).
    /// EOF on stdin counts as `shutdown`, so piping a finite request
    /// stream in exits cleanly even without an explicit shutdown line.
    pub fn run_stdio(&self, engine: &Engine) -> Result<DaemonReport> {
        let (tx, rx) = mpsc::channel::<Event<M::Query, M::Delta>>();
        let queued = Arc::new(AtomicUsize::new(0));
        let writers: Writers = Arc::new(Mutex::new(HashMap::new()));
        writers.lock().unwrap().insert(
            0,
            Arc::new(Mutex::new(
                Box::new(std::io::stdout()) as Box<dyn Write + Send>
            )),
        );
        spawn_reader::<M, C>(
            0,
            Box::new(std::io::stdin()),
            Arc::clone(&self.codec),
            tx,
            Arc::clone(&queued),
            true,
        );
        self.serve_events(engine, rx, &queued, &writers)
    }

    /// The serving loop: pop events, admit, batch, dispatch, refresh.
    fn serve_events(
        &self,
        engine: &Engine,
        rx: mpsc::Receiver<Event<M::Query, M::Delta>>,
        queued: &Arc<AtomicUsize>,
        writers: &Writers,
    ) -> Result<DaemonReport> {
        let config = self.session.config();
        let (hits0, lookups0) = {
            let c = self.session.cache().lock().unwrap();
            (c.hits(), c.lookups())
        };
        let swaps0 = self.session.registry().swap_count();
        let log = Arc::new(DeltaLog::new(self.session.server().n_shards()));
        let mut st = LoopState {
            batcher: MicroBatcher::with_max_wait(config.batch_size, Self::batch_wait_s(config)),
            counters: ServeCounters::default(),
            window: VecDeque::with_capacity(LATENCY_WINDOW),
            served: 0,
            ingested: 0,
            rebuilder: Rebuilder::new(Arc::clone(self.session.registry()), Arc::clone(&log)),
            log,
            conns: HashMap::new(),
        };
        // The idle tick bounds how stale a partial batch or a finished
        // rebuild can get while no events arrive.
        let tick = Duration::from_secs_f64(Self::batch_wait_s(config).clamp(0.0005, 0.005));
        let mut shutdown_from = None;
        loop {
            // Publish finished rebuilds first (on this thread — see the
            // module docs), so the next admission pins the freshest
            // generation.
            st.rebuilder.try_collect();
            match rx.recv_timeout(tick) {
                Ok(ev) => {
                    if let Some(conn) = self.handle_event(engine, &mut st, ev, queued, writers)? {
                        shutdown_from = Some(conn);
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            if let Some(batch) = st.batcher.flush_expired() {
                self.dispatch(engine, &mut st, batch, queued, writers)?;
            }
        }
        // Graceful drain: everything enqueued before the shutdown was
        // processed gets answered (same-connection FIFO means all of
        // the shutting-down client's earlier queries are in here).
        while let Ok(ev) = rx.try_recv() {
            if matches!(ev, Event::Shutdown { .. }) {
                continue;
            }
            self.handle_event(engine, &mut st, ev, queued, writers)?;
        }
        if let Some(batch) = st.batcher.flush() {
            self.dispatch(engine, &mut st, batch, queued, writers)?;
        }
        st.rebuilder.collect_blocking();
        if let Some(conn) = shutdown_from {
            let n = write_line(writers, conn, &Reply::Shutdown { served: st.served });
            st.conns.entry(conn).or_default().bytes += n;
        }
        let (hits, lookups) = {
            let c = self.session.cache().lock().unwrap();
            (c.hits(), c.lookups())
        };
        Ok(DaemonReport {
            served: st.served,
            ingested: st.ingested,
            shed_batches: st.counters.shed_batches,
            cache_hits: (hits - hits0) as usize,
            cache_lookups: (lookups - lookups0) as usize,
            swaps: self.session.registry().swap_count() - swaps0,
            generation: self.session.registry().generation(),
        })
    }

    /// Handle one event; returns the requesting connection when it was
    /// a shutdown.
    fn handle_event(
        &self,
        engine: &Engine,
        st: &mut LoopState<M>,
        ev: Event<M::Query, M::Delta>,
        queued: &Arc<AtomicUsize>,
        writers: &Writers,
    ) -> Result<Option<usize>> {
        match ev {
            Event::Query {
                conn,
                id,
                query,
                queued_at,
            } => {
                queued.fetch_sub(1, Ordering::SeqCst);
                let m = crate::obs::metrics();
                m.queue_depth.set(queued.load(Ordering::SeqCst) as i64);
                m.admission_wait.observe(queued_at.elapsed_s());
                st.conns.entry(conn).or_default().queries += 1;
                let (key, hit) = self
                    .session
                    .server()
                    .probe_cache(query.as_ref(), self.session.cache());
                if let Some(mut o) = hit {
                    // A hit's compute latencies are zero; its delivered
                    // latency is the event-queue wait.
                    let wait = queued_at.elapsed_s();
                    o.initial_latency_s += wait;
                    o.total_latency_s += wait;
                    for tp in &mut o.trace {
                        tp.wall_s += wait;
                    }
                    m.queries.inc();
                    m.serve_initial.observe(o.initial_latency_s);
                    m.serve_total.observe(o.total_latency_s);
                    push_latency(&mut st.window, o.total_latency_s);
                    st.served += 1;
                    let codec = self.codec.as_ref();
                    let reply = response_reply(id, wait, &o, |r| codec.response_to_json(r));
                    let n = write_line(writers, conn, &reply);
                    st.conns.entry(conn).or_default().bytes += n;
                } else if let Some(batch) = st.batcher.push(PendingReq {
                    conn,
                    id,
                    query,
                    key,
                    queued_at,
                }) {
                    self.dispatch(engine, st, batch, queued, writers)?;
                }
                m.batcher_pending.set(st.batcher.pending() as i64);
                Ok(None)
            }
            Event::Ingest { conn, deltas } => {
                let accepted = deltas.len();
                st.log.append_round_robin(deltas);
                st.rebuilder.request_refresh(engine.pool());
                st.ingested += accepted;
                let reply = Reply::Ingested {
                    accepted,
                    generation: self.session.registry().generation(),
                };
                let n = write_line(writers, conn, &reply);
                st.conns.entry(conn).or_default().bytes += n;
                Ok(None)
            }
            Event::Stats { conn } => {
                let body = self.stats_json(st, queued);
                let n = write_line(writers, conn, &Reply::Stats { body });
                st.conns.entry(conn).or_default().bytes += n;
                Ok(None)
            }
            Event::Metrics { conn } => {
                let body = crate::obs::snapshot_json();
                let n = write_line(writers, conn, &Reply::Metrics { body });
                st.conns.entry(conn).or_default().bytes += n;
                Ok(None)
            }
            Event::BadLine { conn, id, message } => {
                crate::obs::metrics().wire_errors.inc();
                let n = write_line(writers, conn, &Reply::Error { id, message });
                let c = st.conns.entry(conn).or_default();
                c.errors += 1;
                c.bytes += n;
                Ok(None)
            }
            Event::Gone { conn } => {
                writers.lock().unwrap().remove(&conn);
                Ok(None)
            }
            Event::Shutdown { conn } => Ok(Some(conn)),
        }
    }

    /// Dispatch one micro-batch through the push-mode executor and
    /// write each response to its connection.
    fn dispatch(
        &self,
        engine: &Engine,
        st: &mut LoopState<M>,
        batch: Vec<PendingReq<M::Query>>,
        queued: &Arc<AtomicUsize>,
        writers: &Writers,
    ) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let config = self.session.config();
        // Live queue depth in batches: undelivered query events plus
        // in-flight rebuilds (both compete for the worker pool) — the
        // signal the shedding policy acts on.
        let in_flight = st.rebuilder.in_flight();
        let pending =
            queued.load(Ordering::SeqCst).div_ceil(config.batch_size.max(1)) + in_flight;
        let during_rebuild = in_flight > 0;
        let mut routes: Vec<(usize, u64, f64)> = Vec::with_capacity(batch.len());
        let admitted: Vec<AdmittedQuery<M>> = batch
            .into_iter()
            .map(|p| {
                let wait = p.queued_at.elapsed_s();
                let tag = routes.len() as u64;
                routes.push((p.conn, p.id, wait));
                AdmittedQuery {
                    tag,
                    query: p.query,
                    key: p.key,
                    queue_wait_s: wait,
                }
            })
            .collect();
        let codec = self.codec.as_ref();
        let window = &mut st.window;
        let served = &mut st.served;
        let mut replies: Vec<(usize, Reply)> = Vec::with_capacity(routes.len());
        self.session.server().serve_admitted(
            engine,
            admitted,
            config,
            pending,
            during_rebuild,
            self.session.cache(),
            &mut st.counters,
            &mut |tag, outcome| {
                let (conn, id, wait) = routes[tag as usize];
                push_latency(window, outcome.total_latency_s);
                *served += 1;
                let reply = response_reply(id, wait, &outcome, |r| codec.response_to_json(r));
                replies.push((conn, reply));
            },
        )?;
        for (conn, reply) in replies {
            let n = write_line(writers, conn, &reply);
            st.conns.entry(conn).or_default().bytes += n;
        }
        Ok(())
    }

    /// The `stats` reply body: counters, live depth, generation, cache
    /// state, recent latency percentiles, per-connection accounting,
    /// the live observability registry snapshot, and the active config.
    fn stats_json(&self, st: &LoopState<M>, queued: &Arc<AtomicUsize>) -> Json {
        let (hits, lookups, len) = {
            let c = self.session.cache().lock().unwrap();
            (c.hits(), c.lookups(), c.len())
        };
        let mut lat: Vec<f64> = st.window.iter().copied().collect();
        lat.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        let connections = Json::Obj(
            st.conns
                .iter()
                .map(|(conn, c)| {
                    (
                        conn.to_string(),
                        Json::obj(vec![
                            ("queries", Json::Num(c.queries as f64)),
                            ("errors", Json::Num(c.errors as f64)),
                            ("bytes", Json::Num(c.bytes as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("app", self.codec.app().into()),
            ("served", Json::Num(st.served as f64)),
            ("queued", queued.load(Ordering::SeqCst).into()),
            ("batcher_pending", st.batcher.pending().into()),
            ("rebuilds_in_flight", st.rebuilder.in_flight().into()),
            (
                "generation",
                Json::Num(self.session.registry().generation() as f64),
            ),
            ("swaps", self.session.registry().swap_count().into()),
            ("ingested", st.ingested.into()),
            ("shed_batches", st.counters.shed_batches.into()),
            ("cache_hits", Json::Num(hits as f64)),
            ("cache_lookups", Json::Num(lookups as f64)),
            ("cache_len", len.into()),
            ("window_p50_ms", (percentile(&lat, 0.50) * 1e3).into()),
            ("window_p99_ms", (percentile(&lat, 0.99) * 1e3).into()),
            ("connections", connections),
            ("metrics", crate::obs::snapshot_json()),
            ("config", self.session.config().to_json()),
        ])
    }
}

/// Append to the bounded latency window, evicting the oldest sample.
fn push_latency(window: &mut VecDeque<f64>, latency_s: f64) {
    if window.len() >= LATENCY_WINDOW {
        window.pop_front();
    }
    window.push_back(latency_s);
}

/// Write one reply line to a connection (serving thread only). A gone
/// or broken connection is ignored — the reply has nowhere to go.
/// Returns the bytes written (line plus newline; 0 when dropped).
fn write_line(writers: &Writers, conn: usize, reply: &Reply) -> u64 {
    let writer = writers.lock().unwrap().get(&conn).cloned();
    let Some(writer) = writer else { return 0 };
    let line = reply.to_line();
    let t0 = std::time::Instant::now();
    {
        let mut w = writer.lock().unwrap();
        let _ = writeln!(w, "{line}");
        let _ = w.flush();
    }
    let m = crate::obs::metrics();
    m.socket_write.observe(t0.elapsed().as_secs_f64());
    m.replies.inc();
    (line.len() + 1) as u64
}

/// Spawn the dedicated reader thread for one connection. Detached: it
/// exits on EOF, a read error, or when the serving loop is gone (its
/// sends start failing). `shutdown_on_eof` makes EOF behave like a
/// `shutdown` request (the stdio transport).
fn spawn_reader<M: Refreshable, C: WireCodec<M>>(
    conn: usize,
    stream: Box<dyn Read + Send>,
    codec: Arc<C>,
    tx: mpsc::Sender<Event<M::Query, M::Delta>>,
    queued: Arc<AtomicUsize>,
    shutdown_on_eof: bool,
) {
    thread::spawn(move || {
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            let event = match Request::parse_line(&line) {
                Ok(Request::Query { id, body }) => match codec.query_from_json(&body) {
                    Ok(q) => {
                        queued.fetch_add(1, Ordering::SeqCst);
                        Event::Query {
                            conn,
                            id,
                            query: Arc::new(q),
                            queued_at: Stopwatch::new(),
                        }
                    }
                    Err(e) => Event::BadLine {
                        conn,
                        id: Some(id),
                        message: e.to_string(),
                    },
                },
                Ok(Request::Ingest { body }) => match decode_deltas(codec.as_ref(), &body) {
                    Ok(deltas) => Event::Ingest { conn, deltas },
                    Err(e) => Event::BadLine {
                        conn,
                        id: None,
                        message: e.to_string(),
                    },
                },
                Ok(Request::Stats) => Event::Stats { conn },
                Ok(Request::Metrics) => Event::Metrics { conn },
                Ok(Request::Shutdown) => {
                    let _ = tx.send(Event::Shutdown { conn });
                    return;
                }
                Err(e) => Event::BadLine {
                    conn,
                    id: None,
                    message: e.to_string(),
                },
            };
            if tx.send(event).is_err() {
                return;
            }
        }
        if shutdown_on_eof {
            let _ = tx.send(Event::Shutdown { conn });
        } else {
            let _ = tx.send(Event::Gone { conn });
        }
    });
}

/// Decode an `ingest` body's `"deltas"` array element-wise.
fn decode_deltas<M: Refreshable, C: WireCodec<M>>(codec: &C, body: &Json) -> Result<Vec<M::Delta>> {
    body.arr_of("deltas")?
        .iter()
        .map(|d| codec.delta_from_json(d))
        .collect()
}
