//! One serving surface over a built model set: [`Session`].
//!
//! A session bundles what every serving mode needs — an epoch-versioned
//! [`ModelRegistry`] over the shards, an answer cache the registry
//! invalidates on every swap, and a validated [`ServeConfig`] — and is
//! built **once**, then driven by whichever mode the caller wants:
//!
//! - [`Session::replay`] — in-process replay of a query log (the
//!   pre-PR-6 `Workbench::serve_*` paths);
//! - [`Session::replay_with_refresh`] — replay with delta ingestion,
//!   background rebuilds and atomic hot-swaps interleaved;
//! - [`crate::serve::daemon::Daemon`] — the long-running JSONL server,
//!   where arrivals and queue depth are real.
//!
//! Collapsing the six per-app `Workbench::serve_*` entry points into
//! this one generic surface is what lets the daemon, the CLI, the
//! benches and the tests share a single code path.
//!
//! Every driving mode records into the process-global observability
//! registry ([`crate::obs`]): the executor stamps per-batch stage
//! spans and latency histograms on all three paths, and the daemon
//! additionally exposes the snapshot over the wire (`metrics`
//! requests, `stats` embedding). `AML_OBS=off` disables recording
//! without touching any serving output.

use std::sync::{Arc, Mutex};

use crate::error::Result;
use crate::mapreduce::engine::Engine;
use crate::refresh::{
    slice_deltas, DeltaLog, ModelRegistry, Rebuilder, RefreshDriver, Refreshable,
};
use crate::serve::cache::AnswerCache;
use crate::serve::executor::{
    QueryOutcome, ServeConfig, ServeReport, ShardedServer, SharedAnswerCache,
};

/// A built, swappable model set plus the cache and config it serves
/// with. See the module docs for the three driving modes.
pub struct Session<M: Refreshable> {
    server: ShardedServer<M>,
    cache: SharedAnswerCache<M::Response>,
    config: ServeConfig,
}

impl<M: Refreshable> Session<M> {
    /// Wrap built shards (at least one) in a fresh registry at
    /// generation 0, with an answer cache of `config.cache_capacity`
    /// entries attached so every future swap invalidates it.
    pub fn new(shards: Vec<Arc<M>>, config: ServeConfig) -> Result<Session<M>> {
        let registry = Arc::new(ModelRegistry::new(shards)?);
        let cache = Arc::new(Mutex::new(AnswerCache::new(config.cache_capacity)));
        registry.attach_cache(Arc::clone(&cache));
        Ok(Session {
            server: ShardedServer::with_registry(registry),
            cache,
            config,
        })
    }

    /// The session's validated serving config.
    pub fn config(&self) -> &ServeConfig {
        &self.config
    }

    /// The session-lifetime answer cache (hit/lookup counters are
    /// lifetime totals; per-replay reports carry deltas).
    pub fn cache(&self) -> &SharedAnswerCache<M::Response> {
        &self.cache
    }

    /// The underlying sharded server.
    pub fn server(&self) -> &ShardedServer<M> {
        &self.server
    }

    /// The epoch-versioned registry rebuilds publish into.
    pub fn registry(&self) -> &Arc<ModelRegistry<M>> {
        self.server.registry()
    }

    /// Replay a query log against the session's cache and config.
    /// Repeat traffic *across* replays hits the shared cache; the
    /// report's cache counters are this replay's deltas.
    pub fn replay(
        &self,
        engine: &Engine,
        queries: Vec<M::Query>,
    ) -> Result<(Vec<QueryOutcome<M::Response>>, ServeReport)> {
        self.server
            .serve_with_cache(engine, queries, &self.config, &self.cache)
    }

    /// Replay with live refresh: `deltas` are cut into one ingestion
    /// slice per refresh cycle (`config.refresh.every` queries apart),
    /// each cycle appends its slice to the delta log and kicks off
    /// background rebuilds, and finished rebuilds hot-swap in between
    /// batches without dropping in-flight queries.
    pub fn replay_with_refresh(
        &self,
        engine: &Engine,
        queries: Vec<M::Query>,
        deltas: Vec<M::Delta>,
    ) -> Result<(Vec<QueryOutcome<M::Response>>, ServeReport)> {
        let log = Arc::new(DeltaLog::new(self.server.n_shards()));
        let rebuilder = Rebuilder::new(Arc::clone(self.registry()), log);
        let cycles = if self.config.refresh.every > 0 {
            queries.len().saturating_sub(1) / self.config.refresh.every
        } else {
            0
        };
        let mut driver = RefreshDriver::new(rebuilder, slice_deltas(deltas, cycles));
        self.server
            .serve_with_refresh(engine, queries, &self.config, &self.cache, &mut driver)
    }
}
