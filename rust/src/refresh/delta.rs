//! The delta-ingestion buffer between live traffic and shard rebuilds.
//!
//! New data (points, users, ratings) arrives while shards are serving;
//! it is appended to a per-shard [`DeltaLog`] and folded into the
//! shard's aggregates by the next background rebuild
//! ([`crate::refresh::Rebuilder`]). The log is append-only between
//! refresh cycles and drained per shard when a rebuild starts; a failed
//! rebuild re-appends its drained deltas so ingested data is never
//! silently dropped.

use std::sync::Mutex;

/// One kNN ingestion record: a feature row and its label (the serving
/// analogue of one new training example).
#[derive(Clone, Debug)]
pub struct LabeledPoint {
    pub features: Vec<f32>,
    pub label: u32,
}

struct Inner<D> {
    per_shard: Vec<Vec<D>>,
    /// Round-robin cursor of [`DeltaLog::append_round_robin`], kept
    /// across calls so successive slices keep rotating.
    cursor: usize,
    /// Records ever appended (drains do not decrement).
    appended: usize,
}

/// Thread-safe per-shard buffer of pending ingestion records.
pub struct DeltaLog<D> {
    inner: Mutex<Inner<D>>,
}

impl<D> DeltaLog<D> {
    /// Log with one buffer per shard (at least one).
    pub fn new(n_shards: usize) -> DeltaLog<D> {
        let n_shards = n_shards.max(1);
        DeltaLog {
            inner: Mutex::new(Inner {
                per_shard: (0..n_shards).map(|_| Vec::new()).collect(),
                cursor: 0,
                appended: 0,
            }),
        }
    }

    /// Number of per-shard buffers.
    pub fn n_shards(&self) -> usize {
        self.inner.lock().unwrap().per_shard.len()
    }

    /// Append one record to a shard's buffer (panics on a bad shard
    /// index — shard count is fixed at construction).
    pub fn append(&self, shard: usize, delta: D) {
        let mut inner = self.inner.lock().unwrap();
        inner.per_shard[shard].push(delta);
        inner.appended += 1;
    }

    /// Distribute records across shards round-robin, continuing from
    /// where the previous call left off (deterministic for a
    /// deterministic input order).
    pub fn append_round_robin(&self, deltas: impl IntoIterator<Item = D>) {
        let mut inner = self.inner.lock().unwrap();
        let n = inner.per_shard.len();
        for d in deltas {
            let s = inner.cursor % n;
            inner.per_shard[s].push(d);
            inner.cursor = (inner.cursor + 1) % n;
            inner.appended += 1;
        }
    }

    /// Records pending across all shards.
    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().per_shard.iter().map(Vec::len).sum()
    }

    /// Records pending for one shard.
    pub fn pending_for(&self, shard: usize) -> usize {
        self.inner.lock().unwrap().per_shard[shard].len()
    }

    /// Take every pending record of one shard (the rebuild handoff).
    pub fn drain(&self, shard: usize) -> Vec<D> {
        std::mem::take(&mut self.inner.lock().unwrap().per_shard[shard])
    }

    /// Records ever appended (ingestion volume; drains do not subtract).
    pub fn total_appended(&self) -> usize {
        self.inner.lock().unwrap().appended
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_drain_per_shard() {
        let log: DeltaLog<u32> = DeltaLog::new(2);
        assert_eq!(log.n_shards(), 2);
        log.append(0, 1);
        log.append(1, 2);
        log.append(0, 3);
        assert_eq!(log.pending(), 3);
        assert_eq!(log.pending_for(0), 2);
        assert_eq!(log.drain(0), vec![1, 3]);
        assert_eq!(log.pending_for(0), 0);
        assert_eq!(log.pending(), 1);
        assert_eq!(log.total_appended(), 3, "drains do not subtract");
    }

    #[test]
    fn round_robin_rotates_across_calls() {
        let log: DeltaLog<u32> = DeltaLog::new(3);
        log.append_round_robin(0..4); // shards 0,1,2,0
        log.append_round_robin(4..6); // continues at 1,2
        assert_eq!(log.drain(0), vec![0, 3]);
        assert_eq!(log.drain(1), vec![1, 4]);
        assert_eq!(log.drain(2), vec![2, 5]);
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let log: DeltaLog<u32> = DeltaLog::new(0);
        assert_eq!(log.n_shards(), 1);
        log.append_round_robin([7, 8]);
        assert_eq!(log.drain(0), vec![7, 8]);
    }
}
