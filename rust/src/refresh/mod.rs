//! Live model refresh: delta ingestion, background shard rebuild, and
//! atomic hot-swap under serving load.
//!
//! The paper's aggregation (Definition 3: bucket means plus index
//! files) is *associative* — absorbing new data into an aggregated
//! point is a weighted-centroid merge, not a rescan — so a serving
//! deployment never has to stop the world to pick up new data. This
//! module is the lifecycle layer that exploits that:
//!
//! * [`ModelRegistry`] — epoch-versioned shard sets. The serve executor
//!   pins one generation per micro-batch at dispatch so in-flight
//!   queries always finish on a consistent shard set; a writer
//!   publishes a replacement generation atomically and the attached
//!   answer cache is invalidated in the same step
//!   ([`crate::serve::AnswerCache::invalidate_all`]), so zero stale
//!   answers survive a swap.
//! * [`DeltaLog`] — the per-shard ingestion buffer new data lands in
//!   while the current generation keeps serving.
//! * [`Rebuilder`] — folds pending deltas into a pinned copy of each
//!   shard as background tasks on the engine's
//!   [`crate::util::pool::WorkerPool`] (serving tasks are never
//!   blocked: the pool pops LIFO, and the serve loop never waits on a
//!   rebuild), validates each candidate, and publishes it as a swap.
//!   [`RefreshDriver`] adapts a rebuilder (plus an ingestion schedule)
//!   to the executor's [`crate::serve::RefreshHook`] for replay runs.
//!
//! The incremental math lives on the models as [`Refreshable`]
//! implementations (`model/{knn,cf,kmeans}.rs`): folding a delta batch
//! in one call is bit-identical to folding it split across any number
//! of calls, because each record is absorbed sequentially by the same
//! weighted-merge arithmetic — the property the refresh tests pin.

pub mod delta;
pub mod rebuilder;
pub mod registry;

pub use delta::{DeltaLog, LabeledPoint};
pub use rebuilder::{slice_deltas, Rebuilder, RefreshDriver, RefreshStats};
pub use registry::{ModelRegistry, ShardSet};

use crate::error::Result;
use crate::model::ServableModel;

/// A servable shard that can absorb new data incrementally.
///
/// `merge_deltas` folds ingestion records into a **new** shard (the
/// receiver is immutable — it may be serving pinned queries right now):
/// each record is routed to the aggregated bucket it belongs with and
/// merged by weighted-centroid / running-mean arithmetic, so the cost
/// is O(deltas × buckets + deltas × dim), not a rescan of the
/// originals. Because records are absorbed sequentially, the fold is
/// associative at the batch level: `base ⊕ (d₁ ++ d₂)` is bit-identical
/// to `(base ⊕ d₁) ⊕ d₂` — rebuilding from scratch over the full log
/// equals the incrementally refreshed shard exactly.
pub trait Refreshable: ServableModel + Sized {
    /// One ingestion record (a labeled point, a user id, a raw point).
    type Delta: Send + Sync + 'static;

    /// Fold `deltas` in order into a candidate replacement shard.
    fn merge_deltas(&self, deltas: &[Self::Delta]) -> Result<Self>;

    /// Amortized housekeeping after a fold, run by the [`Rebuilder`]
    /// between `merge_deltas` and `validate`. Models with bucket-major
    /// storage ([`crate::data::bucket_major`]) re-permute
    /// refresh-appended tail segments into a fresh contiguous base
    /// once the tails grow past the layout's threshold
    /// (`BucketLayout::needs_compaction`); the result must answer
    /// queries bit-identically to the uncompacted shard (row content
    /// per id is unchanged — only physical order moves). Kept separate
    /// from `merge_deltas` so the fold itself stays batch-associative
    /// at physical equality. The default is a no-op.
    fn compact(self) -> Result<Self> {
        Ok(self)
    }

    /// Check a candidate before it may be swapped in: non-empty
    /// buckets, finite aggregates, consistent index accounting (for
    /// bucket-major models, also the offsets/permutation/tail
    /// accounting).
    fn validate(&self) -> Result<()>;
}
