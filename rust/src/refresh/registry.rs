//! The epoch-versioned shard registry behind live model refresh.
//!
//! A [`ModelRegistry`] owns the *current* [`ShardSet`] — an immutable,
//! generation-stamped vector of shard handles — behind one mutex that
//! is only ever held long enough to clone or replace an `Arc`. Readers
//! ([`crate::serve::ShardedServer`]) call [`ModelRegistry::pin`] once
//! per micro-batch at dispatch: the returned `Arc<ShardSet>` keeps that
//! generation's shards alive for as long as the batch runs, so
//! in-flight queries always finish on a consistent shard set no matter
//! how many swaps land meanwhile. Writers (the
//! [`crate::refresh::Rebuilder`]) publish a replacement shard (or a
//! whole set) atomically: later pins see the new generation, earlier
//! pins are untouched, and the old set is freed when its last pin
//! drops.
//!
//! Publishing also fires [`AnswerCache::invalidate_all`] on the
//! attached shared answer cache (when one is attached via
//! [`ModelRegistry::attach_cache`]), so a response computed against the
//! replaced shards can never be replayed after the swap. The
//! swap-then-invalidate order is safe because cache inserts and
//! publishes both happen on the serving thread (the executor inserts
//! between batches; the rebuilder publishes from the executor's refresh
//! hook) — there is no window in which a pre-swap response can be
//! inserted after the invalidation.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::model::ServableModel;
use crate::serve::SharedAnswerCache;

/// One immutable generation of shard handles. Serving pins a whole set,
/// never individual shards, so every shard a batch touches belongs to
/// the same epoch.
pub struct ShardSet<M> {
    generation: u64,
    shards: Vec<Arc<M>>,
}

impl<M> ShardSet<M> {
    /// The epoch this set was published at (0 = the initial build).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The shard handles of this generation.
    pub fn shards(&self) -> &[Arc<M>] {
        &self.shards
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

/// The registry of epoch-versioned shard sets (see the module docs).
pub struct ModelRegistry<M: ServableModel> {
    current: Mutex<Arc<ShardSet<M>>>,
    swap_count: AtomicUsize,
    cache: Mutex<Option<SharedAnswerCache<M::Response>>>,
}

impl<M: ServableModel> ModelRegistry<M> {
    /// Registry starting at generation 0 with the given shards (at
    /// least one).
    pub fn new(shards: Vec<Arc<M>>) -> Result<ModelRegistry<M>> {
        if shards.is_empty() {
            return Err(Error::Engine("registry needs at least one shard".into()));
        }
        Ok(ModelRegistry {
            current: Mutex::new(Arc::new(ShardSet {
                generation: 0,
                shards,
            })),
            swap_count: AtomicUsize::new(0),
            cache: Mutex::new(None),
        })
    }

    /// Pin the current generation: the returned set is immutable and
    /// stays valid (and its shards alive) however many swaps land while
    /// the caller holds it.
    pub fn pin(&self) -> Arc<ShardSet<M>> {
        Arc::clone(&self.current.lock().unwrap())
    }

    /// The current generation number.
    pub fn generation(&self) -> u64 {
        self.current.lock().unwrap().generation
    }

    /// Shards in the current generation.
    pub fn n_shards(&self) -> usize {
        self.current.lock().unwrap().shards.len()
    }

    /// Atomic swaps published so far (single shards and whole sets each
    /// count once).
    pub fn swap_count(&self) -> usize {
        self.swap_count.load(Ordering::SeqCst)
    }

    /// Attach the shared answer cache that serves responses computed
    /// against this registry's shards; every subsequent publish fires
    /// [`crate::serve::AnswerCache::invalidate_all`] on it so stale
    /// answers cannot outlive a swap.
    pub fn attach_cache(&self, cache: SharedAnswerCache<M::Response>) {
        *self.cache.lock().unwrap() = Some(cache);
    }

    /// Publish a replacement for one shard: the new generation carries
    /// the old set with `shards[index]` swapped. Returns the new
    /// generation number.
    pub fn publish_shard(&self, index: usize, shard: Arc<M>) -> Result<u64> {
        let generation = {
            let mut cur = self.current.lock().unwrap();
            if index >= cur.shards.len() {
                return Err(Error::Engine(format!(
                    "publish_shard index {index} out of range ({} shards)",
                    cur.shards.len()
                )));
            }
            let mut shards = cur.shards.clone();
            shards[index] = shard;
            let generation = cur.generation + 1;
            *cur = Arc::new(ShardSet { generation, shards });
            generation
        };
        self.after_publish(generation);
        Ok(generation)
    }

    /// Publish a whole replacement shard set (at least one shard).
    /// Returns the new generation number.
    pub fn publish(&self, shards: Vec<Arc<M>>) -> Result<u64> {
        if shards.is_empty() {
            return Err(Error::Engine("cannot publish an empty shard set".into()));
        }
        let generation = {
            let mut cur = self.current.lock().unwrap();
            let generation = cur.generation + 1;
            *cur = Arc::new(ShardSet { generation, shards });
            generation
        };
        self.after_publish(generation);
        Ok(generation)
    }

    fn after_publish(&self, generation: u64) {
        self.swap_count.fetch_add(1, Ordering::SeqCst);
        crate::obs::metrics().generation.set(generation as i64);
        if let Some(cache) = self.cache.lock().unwrap().as_ref() {
            cache.lock().unwrap().invalidate_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::InitialAnswer;
    use crate::serve::AnswerCache;

    /// Minimal shard: answers with a constant.
    struct Const(i64);

    impl ServableModel for Const {
        type Query = ();
        type Answer = i64;
        type Response = i64;

        fn n_buckets(&self) -> usize {
            1
        }
        fn n_originals(&self) -> usize {
            1
        }
        fn answer_initial(&self, _q: &()) -> InitialAnswer<i64> {
            InitialAnswer {
                answer: self.0,
                correlations: vec![0.0],
            }
        }
        fn refine(&self, _q: &(), initial: &InitialAnswer<i64>, _budget: usize) -> i64 {
            initial.answer
        }
        fn merge(&self, _q: &(), partials: &[i64]) -> i64 {
            partials[0]
        }
        fn accuracy(&self, _q: &(), _r: &i64) -> Option<f64> {
            None
        }
    }

    #[test]
    fn rejects_empty_sets() {
        assert!(ModelRegistry::<Const>::new(vec![]).is_err());
        let reg = ModelRegistry::new(vec![Arc::new(Const(1))]).unwrap();
        assert!(reg.publish(vec![]).is_err());
        assert!(reg.publish_shard(1, Arc::new(Const(2))).is_err());
        assert_eq!(reg.generation(), 0, "failed publishes do not bump the epoch");
        assert_eq!(reg.swap_count(), 0);
    }

    #[test]
    fn pinned_sets_survive_publishes() {
        let reg = ModelRegistry::new(vec![Arc::new(Const(1)), Arc::new(Const(2))]).unwrap();
        let pinned = reg.pin();
        assert_eq!(pinned.generation(), 0);
        assert_eq!(pinned.n_shards(), 2);
        assert_eq!(reg.publish_shard(0, Arc::new(Const(10))).unwrap(), 1);
        // The pin still sees the old epoch...
        assert_eq!(pinned.generation(), 0);
        assert_eq!(pinned.shards()[0].0, 1);
        // ...while a fresh pin sees the new one, with the untouched
        // shard shared (same allocation).
        let fresh = reg.pin();
        assert_eq!(fresh.generation(), 1);
        assert_eq!(fresh.shards()[0].0, 10);
        assert!(Arc::ptr_eq(&fresh.shards()[1], &pinned.shards()[1]));
        assert_eq!(reg.swap_count(), 1);
    }

    #[test]
    fn full_set_publish_bumps_generation() {
        let reg = ModelRegistry::new(vec![Arc::new(Const(1))]).unwrap();
        assert_eq!(reg.publish(vec![Arc::new(Const(5)), Arc::new(Const(6))]).unwrap(), 1);
        assert_eq!(reg.n_shards(), 2);
        assert_eq!(reg.pin().shards()[1].0, 6);
    }

    #[test]
    fn publish_invalidates_the_attached_cache() {
        let reg = ModelRegistry::new(vec![Arc::new(Const(1))]).unwrap();
        let cache: SharedAnswerCache<i64> = Arc::new(Mutex::new(AnswerCache::new(8)));
        cache.lock().unwrap().insert(vec![1], 41);
        reg.attach_cache(Arc::clone(&cache));
        // Without a publish the entry survives.
        assert_eq!(cache.lock().unwrap().get(&[1]), Some(41));
        reg.publish_shard(0, Arc::new(Const(2))).unwrap();
        assert!(cache.lock().unwrap().get(&[1]).is_none(), "swap invalidates");
    }

    #[test]
    fn concurrent_pins_see_a_consistent_epoch() {
        let reg = Arc::new(ModelRegistry::new(vec![Arc::new(Const(0))]).unwrap());
        let writer = {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                for g in 1..=100i64 {
                    reg.publish(vec![Arc::new(Const(g))]).unwrap();
                }
            })
        };
        for _ in 0..1000 {
            let pinned = reg.pin();
            // The pinned set's payload always matches its own epoch —
            // a torn read would pair generation g with shard value != g.
            assert_eq!(pinned.shards()[0].0, pinned.generation() as i64);
        }
        writer.join().unwrap();
        assert_eq!(reg.generation(), 100);
        assert_eq!(reg.swap_count(), 100);
    }
}
