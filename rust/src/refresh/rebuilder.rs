//! Background shard rebuilds with atomic hot-swap.
//!
//! The [`Rebuilder`] turns pending [`crate::refresh::DeltaLog`] records
//! into published shard generations without ever blocking the serving
//! loop:
//!
//! 1. [`Rebuilder::request_refresh`] drains each shard's pending deltas
//!    and submits one rebuild task per shard to the **same**
//!    [`WorkerPool`] the serve executor uses. A rebuild task folds the
//!    deltas into a *pinned* copy of the current shard via the model's
//!    incremental-merge constructor
//!    ([`Refreshable::merge_deltas`]) — base-aggregates ⊕ delta, not a
//!    full rescan — and streams the candidate back on the pool's
//!    **low-priority lane** ([`WorkerPool::stream_into_low`]): serving
//!    tasks always pop first, and at most `WorkerPool::low_cap`
//!    workers run rebuilds at once, so rebuild interference with the
//!    serve path is bounded (reserved workers), not just measured via
//!    p99-during-rebuild.
//! 2. [`Rebuilder::try_collect`] (called from the serving thread
//!    between query admissions) picks up finished candidates without
//!    blocking, validates them ([`Refreshable::validate`]: non-empty
//!    buckets, finite aggregates), and publishes each good one as an
//!    atomic generation swap on the [`ModelRegistry`] — which also
//!    invalidates the attached answer cache. A candidate that fails
//!    validation (or a rebuild that returns an error) re-appends its
//!    drained deltas to the log so ingested data survives for the next
//!    cycle; only a panicking rebuild task loses its in-task batch.
//! 3. [`Rebuilder::collect_blocking`] drains in-flight rebuilds at the
//!    end of a replay so the last cycle's swaps still land.
//!
//! [`RefreshDriver`] packages a `Rebuilder` plus a pre-cut ingestion
//! schedule behind the executor's
//! [`crate::serve::RefreshHook`], which is how the CLI's
//! `serve --refresh-every N --delta-frac F` replay interleaves
//! ingestion with traffic.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc};

use crate::error::Result;
use crate::mapreduce::engine::Engine;
use crate::refresh::delta::DeltaLog;
use crate::refresh::registry::ModelRegistry;
use crate::refresh::Refreshable;
use crate::serve::RefreshHook;
use crate::util::pool::{StreamResult, WorkerPool};

/// What a refresh session did (cumulative over the rebuilder's life).
#[derive(Clone, Copy, Debug, Default)]
pub struct RefreshStats {
    /// Background rebuild tasks submitted.
    pub rebuilds_started: usize,
    /// Candidates validated and atomically swapped in.
    pub swaps: usize,
    /// Rebuilds that failed (merge error, validation failure, panic).
    pub failed: usize,
    /// Delta records folded into published generations.
    pub deltas_merged: usize,
    /// Delta records re-appended to the log after a failed rebuild.
    pub deltas_requeued: usize,
}

/// One finished rebuild: the drained deltas (returned so failures can
/// requeue them) and the candidate shard.
type RebuildOutput<M> = (Vec<<M as Refreshable>::Delta>, Result<M>);

/// Drives background rebuilds and atomic swaps (see the module docs).
pub struct Rebuilder<M: Refreshable> {
    registry: Arc<ModelRegistry<M>>,
    log: Arc<DeltaLog<M::Delta>>,
    tx: mpsc::Sender<StreamResult<RebuildOutput<M>>>,
    rx: mpsc::Receiver<StreamResult<RebuildOutput<M>>>,
    /// Per-shard "rebuild in flight" flags: a shard is never rebuilt
    /// concurrently with itself (the second rebuild would publish over
    /// the first's merged data).
    busy: Vec<bool>,
    in_flight: usize,
    stats: RefreshStats,
}

impl<M: Refreshable> Rebuilder<M> {
    /// Rebuilder over a registry and its delta log (the log must have
    /// one buffer per registry shard).
    pub fn new(registry: Arc<ModelRegistry<M>>, log: Arc<DeltaLog<M::Delta>>) -> Rebuilder<M> {
        let n = registry.n_shards();
        let (tx, rx) = mpsc::channel();
        Rebuilder {
            registry,
            log,
            tx,
            rx,
            busy: vec![false; n],
            in_flight: 0,
            stats: RefreshStats::default(),
        }
    }

    /// The delta log rebuilds drain from.
    pub fn log(&self) -> &Arc<DeltaLog<M::Delta>> {
        &self.log
    }

    /// The registry swaps are published to.
    pub fn registry(&self) -> &Arc<ModelRegistry<M>> {
        &self.registry
    }

    /// Background rebuild tasks currently in flight — the live queue
    /// depth the serve executor's shedding policy reads through
    /// [`RefreshDriver::queue_depth`].
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Cumulative refresh accounting.
    pub fn stats(&self) -> RefreshStats {
        self.stats
    }

    /// Start one background rebuild per shard that has pending deltas
    /// and no rebuild already in flight. Returns how many tasks were
    /// submitted. Never blocks: the candidates surface later through
    /// [`Rebuilder::try_collect`] / [`Rebuilder::collect_blocking`].
    ///
    /// Refreshable shards are those the delta log has a buffer for: if
    /// a writer grows the registry past the log's shard count (a
    /// whole-set [`ModelRegistry::publish`]), the extra shards cannot
    /// receive deltas and are left alone; a shrunk set simply stops
    /// the out-of-range rebuilds from being requested.
    pub fn request_refresh(&mut self, pool: &WorkerPool) -> usize {
        let pinned = self.registry.pin();
        let refreshable = pinned.shards().len().min(self.log.n_shards());
        if self.busy.len() < refreshable {
            self.busy.resize(refreshable, false);
        }
        let mut started = 0;
        for (s, base) in pinned.shards().iter().enumerate().take(refreshable) {
            if self.busy[s] || self.log.pending_for(s) == 0 {
                continue;
            }
            let deltas = self.log.drain(s);
            let base = Arc::clone(base);
            self.busy[s] = true;
            self.in_flight += 1;
            self.stats.rebuilds_started += 1;
            crate::obs::metrics().rebuilds.inc();
            crate::obs::metrics().ingested_deltas.add(deltas.len() as u64);
            started += 1;
            // Rebuild folds score one point at a time (1×d absorb
            // routing) — far below ParallelBackend's auto split
            // threshold, so they never fan helper tiles onto the
            // regular lane and the low-lane reservation math holds.
            // (AML_SPLIT=N forcing is the one debugging exception.)
            // Fold, then amortized compaction (bucket-major models
            // re-permute overgrown tail segments into a fresh base
            // here — off the serving path, on the low lane).
            pool.stream_into_low(&self.tx, s, move || {
                let m = crate::obs::metrics();
                let t0 = std::time::Instant::now();
                let merged = base.merge_deltas(&deltas);
                m.rebuild.observe(t0.elapsed().as_secs_f64());
                let t1 = std::time::Instant::now();
                let candidate = merged.and_then(Refreshable::compact);
                m.compact.observe(t1.elapsed().as_secs_f64());
                (deltas, candidate)
            });
        }
        started
    }

    /// Collect every rebuild that has finished, without blocking.
    /// Returns the number of swaps published.
    pub fn try_collect(&mut self) -> usize {
        let mut swaps = 0;
        while let Ok((s, payload)) = self.rx.try_recv() {
            swaps += usize::from(self.absorb(s, payload));
        }
        swaps
    }

    /// Block until every in-flight rebuild has reported, publishing the
    /// good candidates. Returns the number of swaps published.
    pub fn collect_blocking(&mut self) -> usize {
        let mut swaps = 0;
        while self.in_flight > 0 {
            match self.rx.recv() {
                Ok((s, payload)) => swaps += usize::from(self.absorb(s, payload)),
                Err(_) => break, // our own sender is alive; unreachable
            }
        }
        swaps
    }

    /// Fold one finished rebuild into the registry; true = swapped.
    fn absorb(&mut self, shard: usize, payload: std::thread::Result<RebuildOutput<M>>) -> bool {
        self.in_flight -= 1;
        if let Some(b) = self.busy.get_mut(shard) {
            *b = false;
        }
        match payload {
            Ok((deltas, Ok(candidate))) => {
                let t0 = std::time::Instant::now();
                let published = candidate
                    .validate()
                    .and_then(|_| self.registry.publish_shard(shard, Arc::new(candidate)));
                crate::obs::metrics().swap.observe(t0.elapsed().as_secs_f64());
                match published {
                    Ok(_generation) => {
                        self.stats.swaps += 1;
                        crate::obs::metrics().swaps.inc();
                        self.stats.deltas_merged += deltas.len();
                        true
                    }
                    Err(_) => {
                        self.requeue(shard, deltas);
                        false
                    }
                }
            }
            Ok((deltas, Err(_merge_error))) => {
                self.requeue(shard, deltas);
                false
            }
            Err(_panic) => {
                // The panicking task owned its deltas; they are gone.
                self.stats.failed += 1;
                false
            }
        }
    }

    fn requeue(&mut self, shard: usize, deltas: Vec<M::Delta>) {
        self.stats.failed += 1;
        self.stats.deltas_requeued += deltas.len();
        for d in deltas {
            self.log.append(shard, d);
        }
    }
}

/// A [`Rebuilder`] plus a pre-cut ingestion schedule, packaged behind
/// the serve executor's [`RefreshHook`]: each refresh cycle ingests the
/// next delta slice round-robin across shards and kicks off background
/// rebuilds; every poll publishes whatever candidates have landed.
pub struct RefreshDriver<M: Refreshable> {
    rebuilder: Rebuilder<M>,
    slices: VecDeque<Vec<M::Delta>>,
}

impl<M: Refreshable> RefreshDriver<M> {
    /// Driver ingesting one slice per refresh cycle, in order.
    pub fn new(rebuilder: Rebuilder<M>, slices: Vec<Vec<M::Delta>>) -> RefreshDriver<M> {
        RefreshDriver {
            rebuilder,
            slices: slices.into(),
        }
    }

    /// Refresh accounting so far.
    pub fn stats(&self) -> RefreshStats {
        self.rebuilder.stats()
    }

    /// The driven rebuilder.
    pub fn rebuilder(&self) -> &Rebuilder<M> {
        &self.rebuilder
    }
}

impl<M: Refreshable> RefreshHook<M> for RefreshDriver<M> {
    fn poll(&mut self, _engine: &Engine) -> Result<()> {
        self.rebuilder.try_collect();
        Ok(())
    }

    fn cycle(&mut self, engine: &Engine) -> Result<()> {
        if let Some(slice) = self.slices.pop_front() {
            self.rebuilder.log().append_round_robin(slice);
        }
        self.rebuilder.request_refresh(engine.pool());
        Ok(())
    }

    fn finish(&mut self, engine: &Engine) -> Result<()> {
        self.rebuilder.collect_blocking();
        // Slices the replay never cycled through (a refresh interval
        // longer than the log): ingest and fold them now, so held-back
        // data is never silently dropped — the final generation always
        // reflects the whole reserve.
        if !self.slices.is_empty() {
            for slice in self.slices.drain(..) {
                self.rebuilder.log().append_round_robin(slice);
            }
            self.rebuilder.request_refresh(engine.pool());
            self.rebuilder.collect_blocking();
        }
        Ok(())
    }

    fn queue_depth(&self) -> usize {
        self.rebuilder.in_flight()
    }
}

/// Cut `deltas` into `cycles` near-equal contiguous slices (earlier
/// slices take the remainder), preserving order. `cycles` is clamped
/// to >= 1; empty input yields empty slices.
pub fn slice_deltas<D>(deltas: Vec<D>, cycles: usize) -> Vec<Vec<D>> {
    let cycles = cycles.max(1);
    let n = deltas.len();
    let base = n / cycles;
    let extra = n % cycles;
    let mut out: Vec<Vec<D>> = Vec::with_capacity(cycles);
    let mut it = deltas.into_iter();
    for c in 0..cycles {
        let take = base + usize::from(c < extra);
        out.push(it.by_ref().take(take).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::model::{InitialAnswer, ServableModel};

    /// Toy refreshable shard: the answer is a running sum of absorbed
    /// deltas; negative deltas poison the merge (to exercise failure
    /// requeue) and a sum above 1000 fails validation. `compacted`
    /// records that the rebuilder ran the post-fold compaction hook.
    struct SumModel {
        sum: i64,
        compacted: bool,
    }

    impl ServableModel for SumModel {
        type Query = ();
        type Answer = i64;
        type Response = i64;

        fn n_buckets(&self) -> usize {
            1
        }
        fn n_originals(&self) -> usize {
            1
        }
        fn answer_initial(&self, _q: &()) -> InitialAnswer<i64> {
            InitialAnswer {
                answer: self.sum,
                correlations: vec![0.0],
            }
        }
        fn refine(&self, _q: &(), initial: &InitialAnswer<i64>, _b: usize) -> i64 {
            initial.answer
        }
        fn merge(&self, _q: &(), partials: &[i64]) -> i64 {
            partials.iter().sum()
        }
        fn accuracy(&self, _q: &(), _r: &i64) -> Option<f64> {
            None
        }
    }

    impl Refreshable for SumModel {
        type Delta = i64;

        fn merge_deltas(&self, deltas: &[i64]) -> Result<SumModel> {
            if deltas.iter().any(|&d| d < 0) {
                return Err(Error::Data("poison delta".into()));
            }
            Ok(SumModel {
                sum: self.sum + deltas.iter().sum::<i64>(),
                compacted: false,
            })
        }

        fn compact(self) -> Result<SumModel> {
            Ok(SumModel {
                compacted: true,
                ..self
            })
        }

        fn validate(&self) -> Result<()> {
            if self.sum > 1000 {
                return Err(Error::Data(format!("sum {} too large", self.sum)));
            }
            Ok(())
        }
    }

    fn setup(n_shards: usize) -> (Arc<ModelRegistry<SumModel>>, Rebuilder<SumModel>) {
        let shards = (0..n_shards)
            .map(|_| {
                Arc::new(SumModel {
                    sum: 0,
                    compacted: false,
                })
            })
            .collect();
        let registry = Arc::new(ModelRegistry::new(shards).unwrap());
        let log = Arc::new(DeltaLog::new(n_shards));
        let rebuilder = Rebuilder::new(Arc::clone(&registry), log);
        (registry, rebuilder)
    }

    #[test]
    fn rebuild_merges_and_swaps() {
        let pool = WorkerPool::new(2);
        let (registry, mut rb) = setup(2);
        rb.log().append(0, 5);
        rb.log().append(0, 7);
        rb.log().append(1, 11);
        assert_eq!(rb.request_refresh(&pool), 2);
        assert_eq!(rb.in_flight(), 2);
        assert_eq!(rb.collect_blocking(), 2);
        assert_eq!(rb.in_flight(), 0);
        let pinned = registry.pin();
        assert_eq!(pinned.shards()[0].sum, 12);
        assert_eq!(pinned.shards()[1].sum, 11);
        assert!(pinned.shards()[0].compacted, "rebuild runs the compaction hook");
        assert_eq!(registry.swap_count(), 2);
        let stats = rb.stats();
        assert_eq!(stats.swaps, 2);
        assert_eq!(stats.deltas_merged, 3);
        assert_eq!(stats.failed, 0);
        // Nothing pending: another request is a no-op.
        assert_eq!(rb.request_refresh(&pool), 0);
    }

    #[test]
    fn failed_merge_requeues_deltas() {
        let pool = WorkerPool::new(1);
        let (registry, mut rb) = setup(1);
        rb.log().append(0, -1); // poison: merge_deltas errors
        rb.log().append(0, 3);
        rb.request_refresh(&pool);
        assert_eq!(rb.collect_blocking(), 0);
        assert_eq!(registry.swap_count(), 0, "no swap on failure");
        let stats = rb.stats();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.deltas_requeued, 2);
        assert_eq!(rb.log().pending_for(0), 2, "deltas survive for retry");
    }

    #[test]
    fn invalid_candidate_is_rejected_and_requeued() {
        let pool = WorkerPool::new(1);
        let (registry, mut rb) = setup(1);
        rb.log().append(0, 2000); // merges fine, fails validation
        rb.request_refresh(&pool);
        assert_eq!(rb.collect_blocking(), 0);
        assert_eq!(registry.swap_count(), 0);
        assert_eq!(registry.pin().shards()[0].sum, 0, "old shard still serves");
        assert_eq!(rb.log().pending_for(0), 1);
    }

    #[test]
    fn busy_shards_are_not_rebuilt_concurrently() {
        let pool = WorkerPool::new(1);
        let (_registry, mut rb) = setup(1);
        rb.log().append(0, 1);
        assert_eq!(rb.request_refresh(&pool), 1);
        // More deltas arrive while the rebuild is in flight: the shard
        // is busy, so no second task is submitted...
        rb.log().append(0, 2);
        assert_eq!(rb.request_refresh(&pool), 0);
        rb.collect_blocking();
        // ...and the next cycle picks them up.
        assert_eq!(rb.log().pending_for(0), 1);
        assert_eq!(rb.request_refresh(&pool), 1);
        rb.collect_blocking();
        assert_eq!(rb.registry().pin().shards()[0].sum, 3);
    }

    #[test]
    fn slice_deltas_covers_everything_in_order() {
        let slices = slice_deltas((0..10).collect::<Vec<_>>(), 3);
        assert_eq!(slices, vec![vec![0, 1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]]);
        assert_eq!(slice_deltas(Vec::<u8>::new(), 4).concat(), vec![]);
        assert_eq!(slice_deltas(vec![1u8, 2], 0), vec![vec![1, 2]]);
    }
}
