//! k-means clustering on the MapReduce engine — the third application
//! family the paper motivates (§II lists clustering among the
//! accuracy-input-dependent algorithms; k-means is its canonical
//! example in both Mahout and MLlib).
//!
//! Lloyd iterations as MapReduce rounds: each map task assigns its
//! partition's points to the current centroids and emits per-cluster
//! partial sums; the reduce task combines them into new centroids.
//! AccurateML enters exactly as in the other applications:
//!
//! * stage 1 assigns *aggregated* points, weighted by bucket size —
//!   since k-means centroids are means of means, aggregated points are
//!   a lossless summary whenever a bucket lies wholly inside one
//!   cluster;
//! * the correlation of a bucket (Definition 4) is the negative
//!   *assignment margin* `d₁ − d₂` between its aggregated point's two
//!   nearest centroids: buckets straddling a cluster boundary (small
//!   margin) are where per-point refinement actually moves the result;
//! * stage 2 re-assigns the top ε_max fraction of buckets point by
//!   point, replacing their aggregate contribution.
//!
//! Aggregation is generated once and reused across iterations (the
//! paper's generation step amortizes perfectly in iterative
//! algorithms). Result accuracy is **inertia** (mean squared distance
//! to the final centroids, computed exactly for every mode so the
//! comparison is fair); the loss metric is the relative inertia
//! increase vs the exact run.

use std::sync::Arc;

use crate::approx::algorithm1::{stage2_selection, RefineOrder};
use crate::approx::sampling::sample_rows;
use crate::approx::ProcessingMode;
use crate::data::bucket_major::{BucketLayout, BucketRows};
use crate::data::matrix::{sq_dist, Matrix};
use crate::data::points::{split_rows, RowRange};
use crate::error::Result;
use crate::lsh::bucketizer::Grouping;
use crate::mapreduce::engine::{Engine, MapReduceJob, TwoStageJob};
use crate::mapreduce::metrics::{JobMetrics, TaskMetrics};
use crate::model::kmeans::{argmin_row, build_partition_agg, nearest_centroid};
use crate::model::RescanPath;
use crate::runtime::backend::{GatherBuf, NativeBackend, ScoreBackend};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Configuration of a k-means run.
#[derive(Clone, Debug)]
pub struct KmeansConfig {
    /// Number of clusters.
    pub n_clusters: usize,
    /// Lloyd iterations (each is one MapReduce round).
    pub n_iterations: usize,
    /// Input partitions == map tasks per round.
    pub n_partitions: usize,
    /// Processing mode.
    pub mode: ProcessingMode,
    /// Seed for init / LSH / sampling.
    pub seed: u64,
    /// Bucket grouping strategy (ablation switch).
    pub grouping: Grouping,
    /// Stage-2 selection strategy (ablation switch).
    pub refine_order: RefineOrder,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        KmeansConfig {
            n_clusters: 16,
            n_iterations: 10,
            n_partitions: 20,
            mode: ProcessingMode::Exact,
            seed: 0x4AEA,
            grouping: Grouping::Lsh,
            refine_order: RefineOrder::Correlation,
        }
    }
}

/// Final output of a k-means run.
#[derive(Clone, Debug)]
pub struct KmeansOutput {
    /// (n_clusters × d) final centroids.
    pub centroids: Matrix,
    /// Mean squared distance of every point to its nearest centroid,
    /// computed exactly (mode-independent metric).
    pub inertia: f64,
}

/// Per-partition aggregation cache entry (built once, reused across
/// Lloyd iterations).
struct PartitionAgg {
    /// Bucket centroids (means of member points).
    centers: Matrix,
    /// Bucket → local member rows.
    index: Vec<Vec<u32>>,
    /// Bucket-major permutation of the partition's member rows: bucket
    /// `b`'s points occupy base rows `layout.base_range(b)`, so a
    /// stage-2 re-assignment scores each refined bucket as a contiguous
    /// slice instead of gathering its members every iteration. Built
    /// once alongside the aggregation; the copy amortizes across all
    /// Lloyd rounds.
    layout: BucketLayout,
    rows: BucketRows,
}

/// One Lloyd iteration as a MapReduce job.
struct KmeansIterJob {
    points: Arc<Matrix>,
    partitions: Vec<RowRange>,
    centroids: Matrix,
    mode: ProcessingMode,
    seed: u64,
    refine_order: RefineOrder,
    /// Scoring backend for the stage-2 block reassignments (the
    /// scalar stage-1 assignment stays host-side — it runs once per
    /// aggregated point, not per original).
    backend: Arc<dyn ScoreBackend>,
    /// Stage-2 rescan path: score bucket-major slices in place, or
    /// gather member blocks (the bit-identity reference).
    rescan: RescanPath,
    /// Aggregations per partition (AccurateML mode only). The Option is
    /// None on the first iteration *before* generation — the job then
    /// builds and returns timing through metrics; the runner caches.
    agg: Option<Arc<Vec<PartitionAgg>>>,
}

/// Per-cluster partial result: (sum of assigned vectors, total weight).
type ClusterPartials = Vec<(Vec<f32>, f32)>;

/// Stage-1 → stage-2 carry of one k-means partition: the aggregated
/// partials plus which cluster each bucket went to and which buckets
/// the refinement plan selected.
struct KmeansCarry {
    partials: ClusterPartials,
    assigned: Vec<usize>,
    chosen: Vec<usize>,
}

/// Mean squared distance of every point to its nearest centroid.
fn mean_inertia(points: &Matrix, centroids: &Matrix) -> f64 {
    let mut inertia = 0.0f64;
    for r in 0..points.rows() {
        let (_, d1, _) = nearest_centroid(centroids, points.row(r));
        inertia += d1 as f64;
    }
    inertia / points.rows().max(1) as f64
}

impl KmeansIterJob {
    fn empty_partials(&self) -> ClusterPartials {
        (0..self.centroids.rows())
            .map(|_| (vec![0.0f32; self.points.cols()], 0.0f32))
            .collect()
    }

    fn assign_rows(&self, rows: impl Iterator<Item = usize>, out: &mut ClusterPartials) {
        for r in rows {
            let p = self.points.row(r);
            let (c, _, _) = nearest_centroid(&self.centroids, p);
            let (sum, w) = &mut out[c];
            for (s, &x) in sum.iter_mut().zip(p) {
                *s += x;
            }
            *w += 1.0;
        }
    }

    /// AccurateML stage-1 core: assign aggregated points (weighted by
    /// bucket size) and plan refinement. Returns (partials, bucket →
    /// cluster assignment, chosen buckets).
    fn aggregated_pass(
        &self,
        part_id: usize,
        metrics: &mut TaskMetrics,
    ) -> (ClusterPartials, Vec<usize>, Vec<usize>) {
        let ProcessingMode::AccurateML {
            refinement_threshold,
            ..
        } = self.mode
        else {
            unreachable!("aggregated_pass is only called in AccurateML mode");
        };
        let agg = &self.agg.as_ref().expect("aggregation not built")[part_id];
        let n_buckets = agg.index.len();
        let mut sw = Stopwatch::new();
        let mut out = self.empty_partials();

        // Assign aggregated points; correlation = -(assignment margin).
        let mut assigned = Vec::with_capacity(n_buckets);
        let mut corr = Vec::with_capacity(n_buckets);
        for b in 0..n_buckets {
            let (c, d1, d2) = nearest_centroid(&self.centroids, agg.centers.row(b));
            assigned.push(c);
            corr.push(d1 - d2); // <= 0; near 0 = boundary bucket
            let size = agg.index[b].len() as f32;
            let (sum, w) = &mut out[c];
            for (s, &x) in sum.iter_mut().zip(agg.centers.row(b)) {
                *s += x * size;
            }
            *w += size;
        }
        // Refinement plan (Algorithm 1 lines 2-5).
        let chosen = stage2_selection(
            &corr,
            refinement_threshold,
            self.refine_order,
            self.seed ^ part_id as u64,
        );
        metrics.initial_s += sw.lap_s();
        (out, assigned, chosen)
    }

    /// AccurateML stage 2: re-assign the chosen boundary buckets'
    /// members, replacing their aggregate contribution. Each refined
    /// bucket's centroid distances are computed in ONE backend call per
    /// bucket (PJRT-routed when the backend is). On
    /// [`RescanPath::Slice`] the bucket's rows are never copied: the
    /// bucket-major base segment is scored in place via
    /// [`ScoreBackend::knn_dists_rows`] with the centroids as the query
    /// side (k × members). On [`RescanPath::Gather`] the members are
    /// gathered into a dense block and scored members × k — the
    /// pre-bucket-major behavior, kept as the bit-identity reference.
    /// The per-pair squared distance is operand-symmetric at the bit
    /// level (the kernel contract: `qn + xn − 2·dot` with the dot
    /// accumulated in dimension order, and f32 addition commutes), and
    /// both scatters replay the scalar strict-< first-min
    /// nearest-centroid scan in member order, so the partial sums are
    /// identical on every path.
    fn refine_partials(
        &self,
        part_id: usize,
        mut partials: ClusterPartials,
        assigned: &[usize],
        chosen: &[usize],
        metrics: &mut TaskMetrics,
    ) -> ClusterPartials {
        let range = self.partitions[part_id];
        let agg = &self.agg.as_ref().expect("aggregation not built")[part_id];
        let k = self.centroids.rows();
        let mut sw = Stopwatch::new();
        let mut buf = GatherBuf::default();
        for &b in chosen {
            // Remove the aggregate contribution...
            let size = agg.index[b].len() as f32;
            let (sum, w) = &mut partials[assigned[b]];
            for (s, &x) in sum.iter_mut().zip(agg.centers.row(b)) {
                *s -= x * size;
            }
            *w -= size;
            // ...and add members individually, scored as one block.
            let members = &agg.index[b];
            if members.is_empty() {
                continue; // nothing to re-assign (defensive; buckets are non-empty)
            }
            match self.rescan {
                RescanPath::Gather => {
                    let block = buf.gather(
                        members
                            .iter()
                            .map(|&i| self.points.row(range.start + i as usize)),
                    );
                    let dists = self
                        .backend
                        .knn_dists(&block, &self.centroids)
                        .expect("backend scoring failed");
                    buf.recycle(block);
                    for (r, &i) in members.iter().enumerate() {
                        let p = self.points.row(range.start + i as usize);
                        let (c, _) = argmin_row(dists.row(r));
                        let (sum, w) = &mut partials[c];
                        for (s, &x) in sum.iter_mut().zip(p) {
                            *s += x;
                        }
                        *w += 1.0;
                    }
                }
                RescanPath::Slice => {
                    // Column j is base row b0+j == members[j] (the
                    // batch layout has no tail segments — it is built
                    // once and never refreshed).
                    let (b0, b1) = agg.layout.base_range(b);
                    debug_assert_eq!(b1 - b0, members.len());
                    let dists = self
                        .backend
                        .knn_dists_rows(&self.centroids, agg.rows.base(), b0, b1)
                        .expect("backend scoring failed");
                    for (j, &i) in members.iter().enumerate() {
                        let p = self.points.row(range.start + i as usize);
                        let mut c = 0usize;
                        let mut best = dists.get(0, j);
                        for cc in 1..k {
                            let dv = dists.get(cc, j);
                            if dv < best {
                                best = dv;
                                c = cc;
                            }
                        }
                        let (sum, w) = &mut partials[c];
                        for (s, &x) in sum.iter_mut().zip(p) {
                            *s += x;
                        }
                        *w += 1.0;
                    }
                }
            }
        }
        metrics.refine_s += sw.lap_s();
        partials
    }
}

impl MapReduceJob for KmeansIterJob {
    type MapOut = ClusterPartials;
    type Output = Matrix;

    fn n_partitions(&self) -> usize {
        self.partitions.len()
    }

    fn map(&self, part_id: usize, metrics: &mut TaskMetrics) -> ClusterPartials {
        match self.mode {
            ProcessingMode::AccurateML { .. } => {
                // Barrier mode refines in place — no carry clone, no
                // discarded initial output.
                let (partials, assigned, chosen) = self.aggregated_pass(part_id, metrics);
                self.refine_partials(part_id, partials, &assigned, &chosen, metrics)
            }
            _ => self.stage1(part_id, metrics).0,
        }
    }

    fn shuffle_bytes(&self, out: &ClusterPartials) -> u64 {
        out.iter().map(|(s, _)| (s.len() * 4 + 4) as u64).sum()
    }

    fn shuffle_records(&self, out: &ClusterPartials) -> u64 {
        out.len() as u64
    }

    fn reduce(&self, outs: Vec<ClusterPartials>) -> Matrix {
        self.reduce_ref(&outs)
    }
}

impl TwoStageJob for KmeansIterJob {
    type Carry = KmeansCarry;

    fn stage1(
        &self,
        part_id: usize,
        metrics: &mut TaskMetrics,
    ) -> (ClusterPartials, Option<KmeansCarry>) {
        let range = self.partitions[part_id];
        match self.mode {
            ProcessingMode::Exact => {
                let sw = Stopwatch::new();
                let mut out = self.empty_partials();
                self.assign_rows(range.start..range.end, &mut out);
                metrics.exact_s += sw.elapsed_s();
                (out, None)
            }
            ProcessingMode::Sampling { ratio } => {
                let sw = Stopwatch::new();
                let mut out = self.empty_partials();
                let local = sample_rows(range.len(), ratio, self.seed, part_id as u64);
                self.assign_rows(local.into_iter().map(|i| range.start + i), &mut out);
                metrics.exact_s += sw.elapsed_s();
                (out, None)
            }
            ProcessingMode::AccurateML { .. } => {
                let (partials, assigned, chosen) = self.aggregated_pass(part_id, metrics);
                let carry = KmeansCarry {
                    partials: partials.clone(),
                    assigned,
                    chosen,
                };
                (partials, Some(carry))
            }
        }
    }

    fn stage2(
        &self,
        part_id: usize,
        carry: KmeansCarry,
        metrics: &mut TaskMetrics,
    ) -> ClusterPartials {
        self.refine_partials(part_id, carry.partials, &carry.assigned, &carry.chosen, metrics)
    }

    fn reduce_ref(&self, outs: &[ClusterPartials]) -> Matrix {
        let k = self.centroids.rows();
        let d = self.points.cols();
        let mut next = Matrix::zeros(k, d);
        for c in 0..k {
            let mut sum = vec![0.0f64; d];
            let mut w = 0.0f64;
            for part in outs {
                let (s, pw) = &part[c];
                for (a, &x) in sum.iter_mut().zip(s) {
                    *a += x as f64;
                }
                w += *pw as f64;
            }
            if w > 0.0 {
                for (j, a) in sum.iter().enumerate() {
                    next.set(c, j, (a / w) as f32);
                }
            } else {
                next.row_mut(c).copy_from_slice(self.centroids.row(c));
            }
        }
        next
    }

    /// Trace accuracy is negative inertia (higher is better), computed
    /// exactly over all points against the checkpoint's centroids.
    fn evaluate(&self, centroids: &Matrix) -> f64 {
        -mean_inertia(&self.points, centroids)
    }
}

/// Drives `n_iterations` MapReduce rounds.
pub struct KmeansRunner {
    pub config: KmeansConfig,
    points: Arc<Matrix>,
    backend: Arc<dyn ScoreBackend>,
}

impl KmeansRunner {
    /// New runner over a point set, scoring stage-2 blocks natively.
    pub fn new(config: KmeansConfig, points: Arc<Matrix>) -> Result<KmeansRunner> {
        KmeansRunner::with_backend(config, points, Arc::new(NativeBackend))
    }

    /// New runner with an explicit scoring backend: the stage-2 block
    /// reassignments route through it (PJRT when it is), while the
    /// native backend keeps the historical host-side arithmetic
    /// bit-for-bit.
    pub fn with_backend(
        config: KmeansConfig,
        points: Arc<Matrix>,
        backend: Arc<dyn ScoreBackend>,
    ) -> Result<KmeansRunner> {
        config.mode.validate()?;
        if config.n_clusters == 0 || config.n_clusters > points.rows() {
            return Err(crate::Error::Config(format!(
                "n_clusters {} out of range (points={})",
                config.n_clusters,
                points.rows()
            )));
        }
        Ok(KmeansRunner {
            config,
            points,
            backend,
        })
    }

    /// Run to completion; returns the output and metrics accumulated
    /// over all iterations (aggregation generation counted once).
    pub fn run(&self, engine: &Engine) -> Result<(KmeansOutput, JobMetrics)> {
        self.run_impl(engine, None)
    }

    /// Run every Lloyd iteration on the pipelined streaming engine:
    /// each round's initial (aggregated-assignment) result lands before
    /// its refinement tasks finish, and the per-round accuracy/time
    /// checkpoints are concatenated into the returned metrics' trace.
    pub fn run_streaming(
        &self,
        engine: &Engine,
        checkpoint_every: usize,
    ) -> Result<(KmeansOutput, JobMetrics)> {
        self.run_impl(engine, Some(checkpoint_every))
    }

    fn run_impl(
        &self,
        engine: &Engine,
        streaming: Option<usize>,
    ) -> Result<(KmeansOutput, JobMetrics)> {
        let cfg = &self.config;
        let partitions = split_rows(self.points.rows(), cfg.n_partitions);

        // Init: distinct random rows (deterministic).
        let mut rng = Rng::new(cfg.seed ^ 0x4AEA_11);
        let init_rows = rng.sample_indices(self.points.rows(), cfg.n_clusters);
        let mut centroids = self.points.gather_rows(&init_rows);

        // AccurateML: build per-partition aggregations once via the
        // query-core helper shared with the serving shard builder,
        // timing the generation parts into the first round's metrics.
        let mut gen_metrics = TaskMetrics::default();
        let agg: Option<Arc<Vec<PartitionAgg>>> = match cfg.mode {
            ProcessingMode::AccurateML {
                compression_ratio, ..
            } => {
                let mut parts = Vec::with_capacity(partitions.len());
                for range in &partitions {
                    let (_slice, centers, index) = build_partition_agg(
                        &self.points,
                        *range,
                        compression_ratio,
                        cfg.grouping,
                        cfg.seed,
                        &mut gen_metrics,
                    )?;
                    let layout = BucketLayout::build(&index, range.len())?;
                    let rows = BucketRows::build(&layout, self.points.cols(), |l| {
                        self.points.row(range.start + l as usize)
                    });
                    parts.push(PartitionAgg {
                        centers,
                        index,
                        layout,
                        rows,
                    });
                }
                Some(Arc::new(parts))
            }
            _ => None,
        };

        let mut total = JobMetrics::default();
        let run_sw = Stopwatch::new();
        for _iter in 0..cfg.n_iterations {
            let job = KmeansIterJob {
                points: Arc::clone(&self.points),
                partitions: partitions.clone(),
                centroids: centroids.clone(),
                mode: cfg.mode,
                seed: cfg.seed,
                refine_order: cfg.refine_order,
                backend: Arc::clone(&self.backend),
                rescan: RescanPath::from_env(),
                agg: agg.clone(),
            };
            // Each round's trace restarts its clock; shift onto the
            // run-level axis so the concatenated trajectory is monotone
            // in time. (Refinement counts stay per-round.)
            let iter_start_s = run_sw.elapsed_s();
            let report = match streaming {
                Some(every) => engine.run_streaming(Arc::new(job), every)?,
                None => engine.run(Arc::new(job))?,
            };
            centroids = report.output;
            // Accumulate per-iteration metrics.
            if total.tasks.is_empty() {
                total.tasks = report.metrics.tasks;
            } else {
                for (t, o) in total.tasks.iter_mut().zip(&report.metrics.tasks) {
                    t.add(o);
                }
            }
            total.map_wall_s += report.metrics.map_wall_s;
            total.reduce_wall_s += report.metrics.reduce_wall_s;
            total.shuffle_bytes += report.metrics.shuffle_bytes;
            total.shuffle_records += report.metrics.shuffle_records;
            total.trace.extend(report.metrics.trace.into_iter().map(|mut p| {
                p.wall_s += iter_start_s;
                p
            }));
        }
        // Attribute generation cost once (first task slot is as good a
        // home as any for a per-job one-off; mean_task dilutes it).
        if let Some(t) = total.tasks.first_mut() {
            t.add(&gen_metrics);
        }

        // Exact inertia for fair accuracy comparison.
        let inertia = mean_inertia(&self.points, &centroids);

        Ok((KmeansOutput { centroids, inertia }, total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixtureSpec;

    fn points() -> Arc<Matrix> {
        let d = GaussianMixtureSpec {
            n_points: 2000,
            dim: 8,
            n_classes: 8,
            noise: 0.25,
            test_fraction: 0.01,
            ..Default::default()
        }
        .generate()
        .unwrap();
        Arc::new(d.train)
    }

    fn run(mode: ProcessingMode, pts: Arc<Matrix>) -> (KmeansOutput, JobMetrics) {
        let engine = Engine::new(2);
        let runner = KmeansRunner::new(
            KmeansConfig {
                n_clusters: 8,
                n_iterations: 8,
                n_partitions: 5,
                mode,
                seed: 3,
                ..Default::default()
            },
            pts,
        )
        .unwrap();
        runner.run(&engine).unwrap()
    }

    #[test]
    fn exact_finds_cluster_structure() {
        let pts = points();
        let (out, metrics) = run(ProcessingMode::Exact, pts.clone());
        // Inertia must beat the trivial single-cluster solution by a lot.
        let mut grand = vec![0.0f32; pts.cols()];
        for r in 0..pts.rows() {
            for (g, &x) in grand.iter_mut().zip(pts.row(r)) {
                *g += x;
            }
        }
        for g in grand.iter_mut() {
            *g /= pts.rows() as f32;
        }
        let trivial: f64 = (0..pts.rows())
            .map(|r| sq_dist(pts.row(r), &grand) as f64)
            .sum::<f64>()
            / pts.rows() as f64;
        assert!(
            out.inertia < trivial * 0.5,
            "inertia {} vs trivial {trivial}",
            out.inertia
        );
        assert!(metrics.shuffle_bytes > 0);
    }

    #[test]
    fn accurateml_matches_exact_closely_and_cheaper() {
        let pts = points();
        let (exact, em) = run(ProcessingMode::Exact, pts.clone());
        let (aml, am) = run(
            ProcessingMode::AccurateML {
                compression_ratio: 10.0,
                refinement_threshold: 0.1,
            },
            pts.clone(),
        );
        let loss = (aml.inertia - exact.inertia) / exact.inertia;
        assert!(loss < 0.15, "inertia loss {loss}");
        assert!(
            am.total_map_compute_s() < em.total_map_compute_s(),
            "aml compute {} !< exact {}",
            am.total_map_compute_s(),
            em.total_map_compute_s()
        );
    }

    #[test]
    fn full_refinement_equals_exact() {
        let pts = points();
        let (exact, _) = run(ProcessingMode::Exact, pts.clone());
        let (aml, _) = run(
            ProcessingMode::AccurateML {
                compression_ratio: 10.0,
                refinement_threshold: 1.0,
            },
            pts,
        );
        // ε = 1 refines every bucket => identical assignments.
        assert!(
            (aml.inertia - exact.inertia).abs() < 1e-9,
            "{} vs {}",
            aml.inertia,
            exact.inertia
        );
    }

    #[test]
    fn sampling_full_equals_exact() {
        let pts = points();
        let (exact, _) = run(ProcessingMode::Exact, pts.clone());
        let (s, _) = run(ProcessingMode::Sampling { ratio: 1.0 }, pts);
        assert!((s.inertia - exact.inertia).abs() < 1e-9);
    }

    #[test]
    fn validates_config() {
        let pts = points();
        assert!(KmeansRunner::new(
            KmeansConfig {
                n_clusters: 0,
                ..Default::default()
            },
            pts.clone()
        )
        .is_err());
        assert!(KmeansRunner::new(
            KmeansConfig {
                n_clusters: 1_000_000,
                ..Default::default()
            },
            pts
        )
        .is_err());
    }
}
