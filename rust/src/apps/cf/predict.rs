//! CF prediction accumulation and RMSE.
//!
//! The reduce side of the CF job: neighbor records stream in from map
//! tasks; [`PredictionAccumulator`] folds them into the weighted-average
//! prediction of §III-D:
//!
//! ```text
//! p(u, i) = r̄_u + Σ_v w(u,v) · (r_{v,i} - r̄_v) / Σ_v |w(u,v)|
//! ```

use std::collections::HashMap;

/// One shuffled neighbor record: the weight between an active user and
/// one neighbor (original, aggregated, or sampled), plus the neighbor's
/// rating deviations on the active user's test items.
#[derive(Clone, Debug)]
pub struct NeighborRecord {
    /// Active-user index (into the job's active list).
    pub active: u32,
    /// w(u, v).
    pub weight: f32,
    /// (test item id, r_vi - r̄_v) for items the neighbor rated.
    pub deviations: Vec<(u32, f32)>,
}

impl NeighborRecord {
    /// Shuffle size of this record: weight+active (8 bytes) + one
    /// (item, deviation) pair per entry (8 bytes each).
    pub fn shuffle_bytes(&self) -> u64 {
        8 + (self.deviations.len() * 8) as u64
    }
}

/// Accumulates Σ w·dev and Σ|w| per (active, item).
#[derive(Default)]
pub struct PredictionAccumulator {
    sums: HashMap<(u32, u32), (f64, f64)>,
}

impl PredictionAccumulator {
    /// Fold one record in.
    pub fn add(&mut self, rec: &NeighborRecord) {
        if rec.weight == 0.0 {
            return;
        }
        for &(item, dev) in &rec.deviations {
            let e = self.sums.entry((rec.active, item)).or_insert((0.0, 0.0));
            e.0 += rec.weight as f64 * dev as f64;
            e.1 += rec.weight.abs() as f64;
        }
    }

    /// Predict for (active, item) given the active user's mean rating.
    /// Falls back to the mean when no neighbor evidence arrived.
    pub fn predict(&self, active: u32, item: u32, active_mean: f32) -> f32 {
        match self.sums.get(&(active, item)) {
            Some(&(num, den)) if den > 1e-12 => (active_mean as f64 + num / den) as f32,
            _ => active_mean,
        }
    }

    /// Number of (active, item) cells with evidence.
    pub fn len(&self) -> usize {
        self.sums.len()
    }

    /// True when nothing accumulated.
    pub fn is_empty(&self) -> bool {
        self.sums.is_empty()
    }
}

/// Root-mean-square error between predictions and actual ratings.
pub fn rmse(pairs: &[(f32, f32)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    let s: f64 = pairs
        .iter()
        .map(|&(p, a)| {
            let d = (p - a) as f64;
            d * d
        })
        .sum();
    (s / pairs.len() as f64).sqrt()
}

/// The paper's CF accuracy-loss metric: relative *increase* in RMSE vs
/// exact (clamped at 0).
pub fn rmse_loss(exact_rmse: f64, approx_rmse: f64) -> f64 {
    if exact_rmse <= 0.0 {
        return 0.0;
    }
    ((approx_rmse - exact_rmse) / exact_rmse).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_average_prediction() {
        let mut acc = PredictionAccumulator::default();
        acc.add(&NeighborRecord {
            active: 0,
            weight: 0.5,
            deviations: vec![(7, 1.0)],
        });
        acc.add(&NeighborRecord {
            active: 0,
            weight: -0.25,
            deviations: vec![(7, -2.0)],
        });
        // num = 0.5*1 + (-0.25)(-2) = 1.0; den = 0.75; adj = 4/3.
        let p = acc.predict(0, 7, 3.0);
        assert!((p - (3.0 + 4.0 / 3.0)).abs() < 1e-5);
    }

    #[test]
    fn missing_evidence_falls_back_to_mean() {
        let acc = PredictionAccumulator::default();
        assert_eq!(acc.predict(1, 2, 3.5), 3.5);
    }

    #[test]
    fn zero_weight_records_ignored() {
        let mut acc = PredictionAccumulator::default();
        acc.add(&NeighborRecord {
            active: 0,
            weight: 0.0,
            deviations: vec![(1, 5.0)],
        });
        assert!(acc.is_empty());
    }

    #[test]
    fn rmse_known_values() {
        assert_eq!(rmse(&[]), 0.0);
        let r = rmse(&[(3.0, 3.0), (4.0, 2.0)]);
        assert!((r - (2.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn rmse_loss_direction() {
        assert!((rmse_loss(1.0, 1.1) - 0.1).abs() < 1e-9);
        assert_eq!(rmse_loss(1.0, 0.9), 0.0);
        assert_eq!(rmse_loss(0.0, 1.0), 0.0);
    }

    #[test]
    fn record_shuffle_bytes() {
        let r = NeighborRecord {
            active: 0,
            weight: 0.1,
            deviations: vec![(1, 0.5), (2, -0.5)],
        };
        assert_eq!(r.shuffle_bytes(), 8 + 16);
    }
}
