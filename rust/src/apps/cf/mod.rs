//! User-based CF recommendation on the MapReduce engine (paper §III-D).
//!
//! Map tasks own a partition of training users and emit, per active
//! user, the *neighborhood records* the reducer needs to form the
//! weighted-average prediction — this is the workload whose shuffle
//! volume scales with the processed input (Fig. 5's story):
//!
//! * **Exact** — Pearson weights between every active user and every
//!   partition user; one record per (active, neighbor) pair carrying
//!   the neighbor's rating deviations on that active user's test items.
//! * **AccurateML** — partition users are LSH-bucketed on their
//!   centered rating rows and aggregated (Definition 3 applied to
//!   rating rows, with fractional masks); stage 1 scores aggregated
//!   users (correlation = Pearson weight, per Definition 4) and emits
//!   one record per bucket; stage 2 refines the top ε_max buckets per
//!   active user, replacing the bucket's aggregated record with its
//!   original users' records.
//! * **Sampling** — records from a uniform subset of partition users.
//!
//! The reduce task folds records into Σw·dev / Σ|w| per (active, test
//! item) and reports RMSE (paper §IV-A).

pub mod predict;

use std::sync::Arc;

use crate::approx::algorithm1::{stage2_selection, RefineOrder};
use crate::approx::sampling::sample_rows;
use crate::approx::ProcessingMode;
use crate::apps::STAGE2_BLOCK_QUERIES;
use crate::data::matrix::Matrix;
use crate::data::points::{split_rows, RowRange};
use crate::data::ratings::RatingsSplit;
use crate::error::Result;
use crate::lsh::bucketizer::Grouping;
use crate::mapreduce::engine::{MapReduceJob, TwoStageJob};
use crate::mapreduce::metrics::TaskMetrics;
use crate::model::cf::{user_block, CfModel};
use crate::runtime::backend::ScoreBackend;
use crate::util::timer::Stopwatch;
use predict::{rmse, NeighborRecord, PredictionAccumulator};

/// Configuration of one CF job.
#[derive(Clone, Debug)]
pub struct CfConfig {
    /// Input partitions == map tasks (paper: 100).
    pub n_partitions: usize,
    /// Processing mode.
    pub mode: ProcessingMode,
    /// Seed for LSH / sampling.
    pub seed: u64,
    /// Bucket grouping strategy (ablation switch; default LSH).
    pub grouping: Grouping,
    /// Stage-2 selection strategy (ablation switch; default ranked).
    pub refine_order: RefineOrder,
}

impl Default for CfConfig {
    fn default() -> Self {
        CfConfig {
            n_partitions: 100,
            mode: ProcessingMode::Exact,
            seed: 0xCF_7,
            grouping: Grouping::Lsh,
            refine_order: RefineOrder::Correlation,
        }
    }
}

/// Final output of a CF job.
#[derive(Clone, Debug)]
pub struct CfOutput {
    /// (active user id, item, predicted, actual) per held-out rating.
    pub predictions: Vec<(u32, u32, f32, f32)>,
    /// RMSE over the held-out set.
    pub rmse: f64,
}

/// The job: split + precomputed active-user matrices + backend.
pub struct CfJob {
    config: CfConfig,
    split: Arc<RatingsSplit>,
    backend: Arc<dyn ScoreBackend>,
    partitions: Vec<RowRange>,
    /// (A × m) centered, mask-zeroed active rating rows.
    ca: Matrix,
    /// (A × m) active masks.
    ma: Matrix,
    /// Active users' mean ratings.
    active_means: Vec<f32>,
    /// Every training user's mean rating, precomputed once — the record
    /// emitters need it per (active, neighbor) pair and recomputing it
    /// per record was a measured hot spot (EXPERIMENTS.md §Perf).
    /// Shared (`Arc`) with the per-partition query-core models.
    user_means: Arc<Vec<f32>>,
    /// Test items per active user (parallel to `split.active_users`).
    test_items: Vec<Vec<u32>>,
}

impl CfJob {
    /// Build a job over a train/test split.
    pub fn new(
        config: CfConfig,
        split: Arc<RatingsSplit>,
        backend: Arc<dyn ScoreBackend>,
    ) -> Result<CfJob> {
        config.mode.validate()?;
        let m = split.train.n_items();
        let a = split.active_users.len();
        let mut ca = Matrix::zeros(a, m);
        let mut ma = Matrix::zeros(a, m);
        let mut active_means = Vec::with_capacity(a);
        for (ai, &u) in split.active_users.iter().enumerate() {
            let (row, mean) = split.train.centered_row(u as usize);
            ca.row_mut(ai).copy_from_slice(&row);
            for &i in &split.train.rated[u as usize] {
                ma.set(ai, i as usize, 1.0);
            }
            active_means.push(mean);
        }
        let mut test_items = vec![Vec::new(); a];
        for &(u, i, _) in &split.test {
            let ai = split
                .active_users
                .binary_search(&u)
                .map_err(|_| crate::Error::Data(format!("test user {u} not active")))?;
            test_items[ai].push(i);
        }
        let partitions = split_rows(split.train.n_users(), config.n_partitions);
        let user_means = crate::model::cf::user_means(&split);
        Ok(CfJob {
            config,
            split,
            backend,
            partitions,
            ca,
            ma,
            active_means,
            test_items,
            user_means,
        })
    }

    /// Number of active users.
    pub fn n_active(&self) -> usize {
        self.split.active_users.len()
    }

    /// Emit records for original users `users` (global ids) given their
    /// weight row slice per active user.
    fn records_for_originals(
        &self,
        weights: &Matrix,
        users: &[usize],
        out: &mut Vec<NeighborRecord>,
    ) {
        for ai in 0..self.n_active() {
            let self_id = self.split.active_users[ai] as usize;
            let witems = &self.test_items[ai];
            if witems.is_empty() {
                continue;
            }
            for (r, &v) in users.iter().enumerate() {
                if v == self_id {
                    continue; // a user is not their own neighbor
                }
                let w = weights.get(ai, r);
                if w == 0.0 || !w.is_finite() {
                    continue;
                }
                let vmean = self.user_means[v];
                let mut deviations = Vec::new();
                for &i in witems {
                    if self.split.train.mask.get(v, i as usize) > 0.0 {
                        deviations
                            .push((i, self.split.train.ratings.get(v, i as usize) - vmean));
                    }
                }
                if !deviations.is_empty() {
                    out.push(NeighborRecord {
                        active: ai as u32,
                        weight: w,
                        deviations,
                    });
                }
            }
        }
    }

    /// Exact / sampling scan over a set of users.
    fn scan_users(&self, users: &[usize], metrics: &mut TaskMetrics) -> Vec<NeighborRecord> {
        let sw = Stopwatch::new();
        let (cu, mu) = user_block(&self.split, users);
        let w = self
            .backend
            .cf_weights(&self.ca, &self.ma, &cu, &mu)
            .expect("backend cf_weights failed");
        let mut out = Vec::new();
        self.records_for_originals(&w, users, &mut out);
        metrics.exact_s += sw.elapsed_s();
        out
    }

    /// Emit the aggregated-user record for one (active, bucket) pair if
    /// it carries any evidence for the active user's test items.
    fn aggregated_record(
        &self,
        ai: usize,
        b: usize,
        model: &CfModel,
        wagg: &Matrix,
        out: &mut Vec<NeighborRecord>,
    ) {
        let agg = model.agg();
        let agg_means = model.agg_means();
        let w = wagg.get(ai, b);
        if w == 0.0 || !w.is_finite() {
            return;
        }
        let mut deviations = Vec::new();
        for &i in &self.test_items[ai] {
            if agg.mask.get(b, i as usize) > 0.0 {
                deviations.push((i, agg.ratings.get(b, i as usize) - agg_means[b]));
            }
        }
        if !deviations.is_empty() {
            // The aggregated user enters the prediction as ONE neighbor
            // (its deviations are already bucket means). Scaling its
            // weight by bucket size was tried and measurably hurts
            // RMSE: the aggregated deviations are variance-shrunken,
            // and multiplying their den-share amplifies that bias.
            out.push(NeighborRecord {
                active: ai as u32,
                weight: w,
                deviations,
            });
        }
    }

    /// AccurateML stage-1 core (parts 1-3): build the partition's
    /// query-core model ([`crate::model::cf::CfModel`] — bucketize +
    /// aggregate), score the aggregated users, and plan each active
    /// user's stage-2 refinement (Algorithm 1 lines 2-5). Everything
    /// both the barrier and streaming paths need; the streaming path
    /// additionally materializes [`CfJob::initial_records`].
    fn accurateml_carry(
        &self,
        range: RowRange,
        compression_ratio: f64,
        eps_max: f64,
        metrics: &mut TaskMetrics,
    ) -> CfCarry {
        // Parts 1-2: the model (bucketize + aggregate), built once per
        // partition.
        let model = CfModel::build(
            &self.split,
            &self.user_means,
            range,
            compression_ratio,
            self.config.grouping,
            self.config.refine_order,
            self.config.seed,
            Arc::clone(&self.backend),
            metrics,
        )
        .expect("model build failed");

        // Part 3: score aggregated users and plan stage 2 (Algorithm 1
        // lines 2-5).
        let mut sw = Stopwatch::new();
        let n_buckets = model.n_buckets();
        let wagg = self
            .backend
            .cf_weights(&self.ca, &self.ma, model.cagg(), &model.agg().mask)
            .expect("backend cf_weights failed");
        let mut refined: Vec<Vec<usize>> = Vec::with_capacity(self.n_active());
        for ai in 0..self.n_active() {
            let corr: Vec<f32> = (0..n_buckets).map(|b| wagg.get(ai, b)).collect();
            refined.push(stage2_selection(
                &corr,
                eps_max,
                self.config.refine_order,
                self.config.seed ^ ai as u64,
            ));
        }
        metrics.initial_s += sw.lap_s();

        CfCarry {
            model,
            wagg,
            refined,
        }
    }

    /// The streaming initial output: one record per (active, bucket)
    /// for *every* bucket. Only the streaming path pays for this — the
    /// barrier path goes straight to stage 2.
    fn initial_records(&self, carry: &CfCarry, metrics: &mut TaskMetrics) -> Vec<NeighborRecord> {
        let mut sw = Stopwatch::new();
        let n_buckets = carry.model.n_buckets();
        let mut out = Vec::new();
        for ai in 0..self.n_active() {
            if self.test_items[ai].is_empty() {
                continue;
            }
            for b in 0..n_buckets {
                self.aggregated_record(ai, b, &carry.model, &carry.wagg, &mut out);
            }
        }
        metrics.initial_s += sw.lap_s();
        out
    }

    /// AccurateML stage 2 (Algorithm 1 lines 6-10): the replacement
    /// output — unrefined buckets keep their aggregated record, refined
    /// buckets are replaced by their original users' records. The
    /// refined sets differ per active user, but active users refining
    /// the *same* bucket share one gathered original-user block whose
    /// Pearson weights are computed in ONE `cf_weights` backend call
    /// per bucket-group ([`CfModel::rescan_weight_blocks`]); the
    /// per-user scatter emits records in the old per-pair loop's order
    /// with the same skip rules, so the records are byte-identical on
    /// the native backend.
    fn accurateml_stage2(
        &self,
        carry: &CfCarry,
        metrics: &mut TaskMetrics,
    ) -> Vec<NeighborRecord> {
        let mut sw = Stopwatch::new();
        let n_buckets = carry.model.n_buckets();
        let mut out = Vec::new();
        let mut is_refined = vec![false; n_buckets];
        // Fixed-size micro-batches of active users: scoring the whole
        // active set's weight blocks at once would peak at
        // O(n_active × partition_users); chunking bounds it, and the
        // per-user emission order (ai ascending) is unchanged.
        for start in (0..self.n_active()).step_by(STAGE2_BLOCK_QUERIES) {
            let end = (start + STAGE2_BLOCK_QUERIES).min(self.n_active());
            // Active users with no test items emit nothing — mask
            // their plans so the weight blocks are not scored for them
            // (the old per-pair loop skipped them before any weight
            // was computed).
            let plans: Vec<Vec<usize>> = (start..end)
                .map(|ai| {
                    if self.test_items[ai].is_empty() {
                        Vec::new()
                    } else {
                        carry.refined[ai].clone()
                    }
                })
                .collect();
            let q_cu: Vec<&[f32]> = (start..end).map(|ai| self.ca.row(ai)).collect();
            let q_mu: Vec<&[f32]> = (start..end).map(|ai| self.ma.row(ai)).collect();
            let (blocks, grouped) = carry.model.rescan_weight_blocks(&q_cu, &q_mu, &plans);
            for ai in start..end {
                let local = ai - start;
                let witems = &self.test_items[ai];
                if witems.is_empty() {
                    continue;
                }
                is_refined.fill(false);
                for &b in &plans[local] {
                    is_refined[b] = true;
                }
                // Aggregated records that survive refinement.
                for b in 0..n_buckets {
                    if !is_refined[b] {
                        self.aggregated_record(ai, b, &carry.model, &carry.wagg, &mut out);
                    }
                }
                // Refined buckets: original users replace the
                // aggregate, their weights read from the shared scored
                // blocks.
                let self_id = self.split.active_users[ai] as usize;
                for (j, &b) in plans[local].iter().enumerate() {
                    let block = blocks[b].as_ref().expect("scored bucket group");
                    let (head, tail) = block.parts(grouped.slots[local][j]);
                    carry
                        .model
                        .for_each_original_weighted(b, head, tail, Some(self_id), |v, w| {
                            let vmean = self.user_means[v];
                            let mut deviations = Vec::new();
                            for &i in witems {
                                if self.split.train.mask.get(v, i as usize) > 0.0 {
                                    deviations.push((
                                        i,
                                        self.split.train.ratings.get(v, i as usize) - vmean,
                                    ));
                                }
                            }
                            if !deviations.is_empty() {
                                out.push(NeighborRecord {
                                    active: ai as u32,
                                    weight: w,
                                    deviations,
                                });
                            }
                        });
                }
            }
        }
        metrics.refine_s += sw.lap_s();
        out
    }
}

/// Stage-1 → stage-2 carry of one CF partition: the partition's
/// query-core model (users, centered rows/masks, aggregation), the
/// stage-1 weight block and the per-active refinement plan.
pub struct CfCarry {
    model: CfModel,
    wagg: Matrix,
    refined: Vec<Vec<usize>>,
}

impl MapReduceJob for CfJob {
    type MapOut = Vec<NeighborRecord>;
    type Output = CfOutput;

    fn n_partitions(&self) -> usize {
        self.partitions.len()
    }

    fn map(&self, part_id: usize, metrics: &mut TaskMetrics) -> Self::MapOut {
        let range = self.partitions[part_id];
        if range.is_empty() {
            return Vec::new();
        }
        match self.config.mode {
            ProcessingMode::AccurateML {
                compression_ratio,
                refinement_threshold,
            } => {
                // Barrier mode skips the initial output: only the
                // refined result ships.
                let carry =
                    self.accurateml_carry(range, compression_ratio, refinement_threshold, metrics);
                self.accurateml_stage2(&carry, metrics)
            }
            _ => self.stage1(part_id, metrics).0,
        }
    }

    fn shuffle_bytes(&self, out: &Self::MapOut) -> u64 {
        out.iter().map(|r| r.shuffle_bytes()).sum()
    }

    fn shuffle_records(&self, out: &Self::MapOut) -> u64 {
        out.len() as u64
    }

    fn reduce(&self, outs: Vec<Self::MapOut>) -> CfOutput {
        self.reduce_ref(&outs)
    }
}

impl TwoStageJob for CfJob {
    type Carry = CfCarry;

    fn stage1(&self, part_id: usize, metrics: &mut TaskMetrics) -> (Self::MapOut, Option<CfCarry>) {
        let range = self.partitions[part_id];
        if range.is_empty() {
            return (Vec::new(), None);
        }
        match self.config.mode {
            ProcessingMode::Exact => {
                let users: Vec<usize> = (range.start..range.end).collect();
                (self.scan_users(&users, metrics), None)
            }
            ProcessingMode::Sampling { ratio } => {
                let local = sample_rows(range.len(), ratio, self.config.seed, part_id as u64);
                if local.is_empty() {
                    return (Vec::new(), None);
                }
                let users: Vec<usize> = local.iter().map(|&i| range.start + i).collect();
                (self.scan_users(&users, metrics), None)
            }
            ProcessingMode::AccurateML {
                compression_ratio,
                refinement_threshold,
            } => {
                let carry =
                    self.accurateml_carry(range, compression_ratio, refinement_threshold, metrics);
                let initial = self.initial_records(&carry, metrics);
                (initial, Some(carry))
            }
        }
    }

    fn stage2(&self, _part_id: usize, carry: CfCarry, metrics: &mut TaskMetrics) -> Self::MapOut {
        self.accurateml_stage2(&carry, metrics)
    }

    fn reduce_ref(&self, outs: &[Self::MapOut]) -> CfOutput {
        let mut acc = PredictionAccumulator::default();
        for records in outs {
            for r in records {
                acc.add(r);
            }
        }
        let mut predictions = Vec::with_capacity(self.split.test.len());
        let mut pairs = Vec::with_capacity(self.split.test.len());
        for &(u, i, actual) in &self.split.test {
            let ai = self.split.active_users.binary_search(&u).unwrap();
            let p = acc
                .predict(ai as u32, i, self.active_means[ai])
                .clamp(1.0, 5.0);
            predictions.push((u, i, p, actual));
            pairs.push((p, actual));
        }
        CfOutput {
            predictions,
            rmse: rmse(&pairs),
        }
    }

    /// Trace accuracy for CF is negative RMSE (higher is better).
    fn evaluate(&self, output: &CfOutput) -> f64 {
        -output.rmse
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ratings::{LatentFactorSpec, RatingsSplit};
    use crate::mapreduce::engine::Engine;
    use crate::runtime::backend::NativeBackend;

    fn split() -> Arc<RatingsSplit> {
        let m = LatentFactorSpec {
            n_users: 400,
            n_items: 96,
            n_factors: 4,
            mean_ratings_per_user: 24,
            ..Default::default()
        }
        .generate()
        .unwrap();
        Arc::new(RatingsSplit::new(&m, 20, 0.2, 9).unwrap())
    }

    fn run(
        mode: ProcessingMode,
        split: Arc<RatingsSplit>,
    ) -> (CfOutput, crate::mapreduce::JobMetrics) {
        let engine = Engine::new(4);
        let job = CfJob::new(
            CfConfig {
                n_partitions: 8,
                mode,
                seed: 3,
                ..Default::default()
            },
            split,
            Arc::new(NativeBackend),
        )
        .unwrap();
        let report = engine.run(Arc::new(job)).unwrap();
        (report.output, report.metrics)
    }

    #[test]
    fn exact_beats_mean_baseline() {
        let s = split();
        let (out, metrics) = run(ProcessingMode::Exact, s.clone());
        assert_eq!(out.predictions.len(), s.test.len());
        // Baseline: predict each active user's mean.
        let job = CfJob::new(CfConfig::default(), s.clone(), Arc::new(NativeBackend)).unwrap();
        let mean_pairs: Vec<(f32, f32)> = s
            .test
            .iter()
            .map(|&(u, _i, r)| {
                let ai = s.active_users.binary_search(&u).unwrap();
                (job.active_means[ai], r)
            })
            .collect();
        let mean_rmse = rmse(&mean_pairs);
        assert!(
            out.rmse < mean_rmse,
            "CF rmse {} not better than mean baseline {mean_rmse}",
            out.rmse
        );
        assert!(metrics.shuffle_bytes > 0);
    }

    #[test]
    fn accurateml_rmse_close_to_exact_with_smaller_shuffle() {
        let s = split();
        let (exact, em) = run(ProcessingMode::Exact, s.clone());
        let (aml, am) = run(
            ProcessingMode::AccurateML {
                compression_ratio: 10.0,
                refinement_threshold: 0.1,
            },
            s.clone(),
        );
        let loss = predict::rmse_loss(exact.rmse, aml.rmse);
        assert!(loss < 0.30, "rmse loss {loss} too large");
        assert!(
            am.shuffle_bytes < em.shuffle_bytes,
            "AccurateML shuffle {} !< exact {}",
            am.shuffle_bytes,
            em.shuffle_bytes
        );
        let mean = am.mean_task();
        assert!(mean.lsh_s > 0.0 && mean.aggregate_s > 0.0);
    }

    #[test]
    fn sampling_full_ratio_equals_exact() {
        let s = split();
        let (exact, _) = run(ProcessingMode::Exact, s.clone());
        let (samp, _) = run(ProcessingMode::Sampling { ratio: 1.0 }, s);
        assert!((exact.rmse - samp.rmse).abs() < 1e-9);
        assert_eq!(exact.predictions, samp.predictions);
    }

    #[test]
    fn sampling_low_ratio_worse_than_accurateml() {
        // The paper's core comparison at a matched input budget: 10%
        // sampling vs r=10 aggregation (both touch ~10% "volume").
        let s = split();
        let (exact, _) = run(ProcessingMode::Exact, s.clone());
        let (aml, _) = run(
            ProcessingMode::AccurateML {
                compression_ratio: 10.0,
                refinement_threshold: 0.05,
            },
            s.clone(),
        );
        let (samp, _) = run(ProcessingMode::Sampling { ratio: 0.1 }, s);
        let aml_loss = predict::rmse_loss(exact.rmse, aml.rmse);
        let samp_loss = predict::rmse_loss(exact.rmse, samp.rmse);
        assert!(
            aml_loss <= samp_loss + 0.02,
            "aml loss {aml_loss} vs sampling loss {samp_loss}"
        );
    }
}
