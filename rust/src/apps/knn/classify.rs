//! kNN voting and accuracy metrics.

/// A scored candidate: (squared distance, class label).
pub type LabeledCandidate = (f32, u32);

/// Merge per-partition candidate lists for one test point and keep the
/// global k nearest. Inputs need not be sorted; output is ascending.
pub fn merge_candidates(lists: &[Vec<LabeledCandidate>], k: usize) -> Vec<LabeledCandidate> {
    let mut all: Vec<LabeledCandidate> = lists.iter().flatten().copied().collect();
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    all.truncate(k);
    all
}

/// Majority vote among the candidates' labels; ties break to the label
/// with the nearest member (then to the smaller label), so results are
/// deterministic.
pub fn majority_vote(candidates: &[LabeledCandidate]) -> u32 {
    use std::collections::BTreeMap;
    if candidates.is_empty() {
        return 0;
    }
    let mut counts: BTreeMap<u32, (usize, f32)> = BTreeMap::new();
    for &(dist, label) in candidates {
        let e = counts.entry(label).or_insert((0, f32::INFINITY));
        e.0 += 1;
        if dist < e.1 {
            e.1 = dist;
        }
    }
    counts
        .into_iter()
        .min_by(|a, b| {
            // Most votes first, then nearest representative, then label.
            b.1 .0
                .cmp(&a.1 .0)
                .then(a.1 .1.partial_cmp(&b.1 .1).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.0.cmp(&b.0))
        })
        .map(|(label, _)| label)
        .unwrap_or(0)
}

/// Fraction of predictions matching the true labels.
pub fn classification_accuracy(predicted: &[u32], actual: &[u32]) -> f64 {
    assert_eq!(predicted.len(), actual.len());
    if predicted.is_empty() {
        return 0.0;
    }
    let correct = predicted
        .iter()
        .zip(actual)
        .filter(|(p, a)| p == a)
        .count();
    correct as f64 / predicted.len() as f64
}

/// The paper's accuracy-loss metric (§IV-A): relative decrease of
/// approximate accuracy vs exact accuracy. Clamped at 0 (an approximate
/// result can tie or beat exact by luck; the paper reports losses).
pub fn accuracy_loss(exact_accuracy: f64, approx_accuracy: f64) -> f64 {
    if exact_accuracy <= 0.0 {
        return 0.0;
    }
    ((exact_accuracy - approx_accuracy) / exact_accuracy).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_keeps_global_nearest() {
        let a = vec![(0.5, 1u32), (2.0, 2)];
        let b = vec![(0.1, 3), (3.0, 1)];
        let merged = merge_candidates(&[a, b], 3);
        assert_eq!(
            merged.iter().map(|c| c.1).collect::<Vec<_>>(),
            vec![3, 1, 2]
        );
    }

    #[test]
    fn vote_majority_wins() {
        let c = vec![(0.1, 2u32), (0.2, 1), (0.3, 1), (0.4, 1), (0.5, 2)];
        assert_eq!(majority_vote(&c), 1);
    }

    #[test]
    fn vote_tie_breaks_to_nearest() {
        let c = vec![(0.1, 5u32), (0.2, 3), (0.3, 5), (0.4, 3)];
        // 2-2 tie; label 5 has the nearest member (0.1).
        assert_eq!(majority_vote(&c), 5);
    }

    #[test]
    fn vote_empty_is_zero() {
        assert_eq!(majority_vote(&[]), 0);
    }

    #[test]
    fn accuracy_and_loss() {
        assert_eq!(classification_accuracy(&[1, 2, 3], &[1, 2, 4]), 2.0 / 3.0);
        assert!((accuracy_loss(0.8, 0.72) - 0.1).abs() < 1e-12);
        assert_eq!(accuracy_loss(0.8, 0.9), 0.0);
        assert_eq!(accuracy_loss(0.0, 0.5), 0.0);
    }
}
