//! kNN classification on the MapReduce engine (paper §III-D).
//!
//! One [`KnnJob`] implements all three processing modes inside its map
//! task:
//!
//! * **Exact** — scan every training row of the partition per test
//!   point (the basic map task of Fig. 2a); emits each test point's k
//!   nearest (distance, label) candidates.
//! * **AccurateML** — Fig. 2b: LSH-bucket the partition, aggregate
//!   buckets into centroids (timed as Fig. 4's parts 1-2), run
//!   Algorithm 1 per test point: distances to centroids give both the
//!   initial candidates and the correlations (negative distance, per
//!   Definition 4's kNN discussion); the top ε_max fraction of buckets
//!   is refined by scanning its original rows (parts 3-4).
//! * **Sampling** — scan a uniform subset (the §IV-C baseline).
//!
//! The reduce task merges per-partition candidates, takes the global
//! top-k per test point and majority-votes the class — identical for
//! every mode, which is what makes the accuracy comparison fair.

pub mod classify;

use std::sync::Arc;

use crate::approx::algorithm1::RefineOrder;
use crate::approx::sampling::sample_rows;
use crate::approx::ProcessingMode;
use crate::apps::STAGE2_BLOCK_QUERIES;
use crate::data::gaussian::LabeledPoints;
use crate::data::matrix::Matrix;
use crate::data::points::{split_rows, RowRange};
use crate::error::Result;
use crate::lsh::bucketizer::Grouping;
use crate::mapreduce::engine::{MapReduceJob, TwoStageJob};
use crate::mapreduce::metrics::TaskMetrics;
use crate::model::knn::KnnModel;
use crate::runtime::backend::{ScoreBackend, TopK};
use crate::util::timer::Stopwatch;
use classify::{classification_accuracy, majority_vote, merge_candidates, LabeledCandidate};

/// Configuration of one kNN job.
#[derive(Clone, Debug)]
pub struct KnnConfig {
    /// Number of neighbors (paper: 5; Fig. 9 sweeps 10/20/50).
    pub k: usize,
    /// Input partitions == map tasks (paper: 100).
    pub n_partitions: usize,
    /// Processing mode.
    pub mode: ProcessingMode,
    /// Seed for LSH / sampling.
    pub seed: u64,
    /// Bucket grouping strategy (ablation switch; default LSH).
    pub grouping: Grouping,
    /// Stage-2 selection strategy (ablation switch; default ranked).
    pub refine_order: RefineOrder,
}

impl Default for KnnConfig {
    fn default() -> Self {
        KnnConfig {
            k: 5,
            n_partitions: 100,
            mode: ProcessingMode::Exact,
            seed: 0x14AA,
            grouping: Grouping::Lsh,
            refine_order: RefineOrder::Correlation,
        }
    }
}

/// Final output of a kNN job.
#[derive(Clone, Debug)]
pub struct KnnOutput {
    /// Predicted label per test point.
    pub predictions: Vec<u32>,
    /// Classification accuracy vs the test labels.
    pub accuracy: f64,
}

/// The job: shared dataset + backend + mode.
pub struct KnnJob {
    config: KnnConfig,
    data: Arc<LabeledPoints>,
    backend: Arc<dyn ScoreBackend>,
    partitions: Vec<RowRange>,
}

impl KnnJob {
    /// Build a job over a dataset.
    pub fn new(
        config: KnnConfig,
        data: Arc<LabeledPoints>,
        backend: Arc<dyn ScoreBackend>,
    ) -> Result<KnnJob> {
        config.mode.validate()?;
        if config.k == 0 {
            return Err(crate::Error::Config("k must be positive".into()));
        }
        let partitions = split_rows(data.train.rows(), config.n_partitions);
        Ok(KnnJob {
            config,
            data,
            backend,
            partitions,
        })
    }

    /// Dataset accessor (used by reports).
    pub fn data(&self) -> &LabeledPoints {
        &self.data
    }

    /// Exact scan of (a subset of) the partition rows.
    fn scan_rows(
        &self,
        rows: &[usize],
        metrics: &mut TaskMetrics,
    ) -> Vec<Vec<LabeledCandidate>> {
        let sw = Stopwatch::new();
        let part = self.data.train.gather_rows(rows);
        let found = self
            .backend
            .knn_block_topk(&self.data.test, &part, self.config.k)
            .expect("backend scoring failed");
        let out = found
            .into_iter()
            .map(|cands| {
                cands
                    .into_iter()
                    .map(|(d, local)| (d, self.data.train_labels[rows[local as usize]]))
                    .collect()
            })
            .collect();
        metrics.exact_s += sw.elapsed_s();
        out
    }

    /// AccurateML stage-1 core (Fig. 2b parts 1-3 + Algorithm 1 lines
    /// 2-5): build the partition's query-core model
    /// ([`crate::model::knn::KnnModel`] — bucketize + aggregate), score
    /// the aggregated points, and plan each test point's stage-2
    /// refinement. Everything both the barrier and the streaming paths
    /// need; the streaming path additionally materializes
    /// [`KnnJob::initial_candidates`].
    fn accurateml_carry(
        &self,
        range: RowRange,
        compression_ratio: f64,
        eps_max: f64,
        metrics: &mut TaskMetrics,
    ) -> KnnCarry {
        // Parts 1-2: the model (bucketize + aggregate), built once per
        // partition.
        let model = KnnModel::build(
            &self.data.train,
            &self.data.train_labels,
            range,
            self.config.k,
            compression_ratio,
            self.config.grouping,
            self.config.refine_order,
            self.config.seed,
            Arc::clone(&self.backend),
            metrics,
        )
        .expect("model build failed");

        // Part 3: initial outputs from aggregated points. One dense
        // distance block: (test × centroids). Correlation of bucket b
        // for test point t is -dists[t][b] (Definition 4); ranking it
        // plans stage 2 (Algorithm 1 lines 2-5).
        let mut sw = Stopwatch::new();
        let dists = model.score_block(&self.data.test);
        let mut refined = Vec::with_capacity(self.data.test.rows());
        for t in 0..self.data.test.rows() {
            refined.push(model.plan(dists.row(t), eps_max, self.config.seed ^ t as u64));
        }
        metrics.initial_s += sw.lap_s();

        KnnCarry {
            model,
            dists,
            refined,
        }
    }

    /// The streaming initial output: every bucket's aggregated point as
    /// a candidate, per test point. Only the streaming path pays for
    /// this — the barrier path goes straight to stage 2. One selection
    /// heap is drained per test point instead of allocating |test|
    /// heaps (the same scratch pattern as the stage-2 loop below).
    fn initial_candidates(
        &self,
        carry: &KnnCarry,
        metrics: &mut TaskMetrics,
    ) -> Vec<Vec<LabeledCandidate>> {
        let mut sw = Stopwatch::new();
        let mut initial = Vec::with_capacity(self.data.test.rows());
        let mut topk = TopK::new(self.config.k);
        for t in 0..self.data.test.rows() {
            initial.push(carry.model.initial_topk_with(carry.dists.row(t), &mut topk));
        }
        metrics.initial_s += sw.lap_s();
        initial
    }

    /// AccurateML stage 2 (Algorithm 1 lines 6-10): the whole test
    /// set's refinement plans run through the model's bucket-grouped
    /// block core ([`KnnModel::refine_rows_block`]) — test points that
    /// refine the *same* bucket share one gathered original-row block
    /// and ONE backend call, and the per-query scatter preserves each
    /// plan's Algorithm-1 order, so the emitted candidates are
    /// byte-identical to the old per-query `refine_query` loop on the
    /// native backend.
    fn accurateml_stage2(
        &self,
        carry: &KnnCarry,
        metrics: &mut TaskMetrics,
    ) -> Vec<Vec<LabeledCandidate>> {
        let mut sw = Stopwatch::new();
        let n_test = self.data.test.rows();
        // Fixed-size micro-batches (the serving executor's shape):
        // refine_rows_block materializes one scored block per refined
        // bucket before scattering, so feeding the whole test set at
        // once would peak at O(n_test × partition_rows) per task.
        // Chunking bounds that; per-query results are independent, so
        // the concatenation is identical to one big block.
        let mut out = Vec::with_capacity(n_test);
        for start in (0..n_test).step_by(STAGE2_BLOCK_QUERIES) {
            let end = (start + STAGE2_BLOCK_QUERIES).min(n_test);
            let qrows: Vec<&[f32]> = (start..end).map(|t| self.data.test.row(t)).collect();
            let drows: Vec<&[f32]> = (start..end).map(|t| carry.dists.row(t)).collect();
            let (chunk, _bucket_groups) =
                carry.model.refine_rows_block(&qrows, &drows, &carry.refined[start..end]);
            out.extend(chunk);
        }
        metrics.refine_s += sw.lap_s();
        out
    }
}

/// Stage-1 → stage-2 carry of one kNN partition: the partition's
/// query-core model, the stage-1 distance block and the per-test
/// refinement plan (Algorithm 1 lines 2-5, already ranked).
pub struct KnnCarry {
    model: KnnModel,
    dists: Matrix,
    refined: Vec<Vec<usize>>,
}

impl MapReduceJob for KnnJob {
    /// Per test point: k candidate (distance, label) pairs.
    type MapOut = Vec<Vec<LabeledCandidate>>;
    type Output = KnnOutput;

    fn n_partitions(&self) -> usize {
        self.partitions.len()
    }

    fn map(&self, part_id: usize, metrics: &mut TaskMetrics) -> Self::MapOut {
        let range = self.partitions[part_id];
        if range.is_empty() {
            return vec![Vec::new(); self.data.test.rows()];
        }
        match self.config.mode {
            ProcessingMode::AccurateML {
                compression_ratio,
                refinement_threshold,
            } => {
                // Barrier mode skips the initial output: only the
                // refined result ships.
                let carry =
                    self.accurateml_carry(range, compression_ratio, refinement_threshold, metrics);
                self.accurateml_stage2(&carry, metrics)
            }
            _ => self.stage1(part_id, metrics).0,
        }
    }

    fn shuffle_bytes(&self, out: &Self::MapOut) -> u64 {
        // One candidate = f32 distance + u32 label.
        out.iter().map(|c| (c.len() * 8) as u64).sum()
    }

    fn shuffle_records(&self, out: &Self::MapOut) -> u64 {
        out.iter().map(|c| c.len() as u64).sum()
    }

    fn reduce(&self, outs: Vec<Self::MapOut>) -> KnnOutput {
        self.reduce_ref(&outs)
    }
}

impl TwoStageJob for KnnJob {
    type Carry = KnnCarry;

    fn stage1(
        &self,
        part_id: usize,
        metrics: &mut TaskMetrics,
    ) -> (Self::MapOut, Option<KnnCarry>) {
        let range = self.partitions[part_id];
        if range.is_empty() {
            return (vec![Vec::new(); self.data.test.rows()], None);
        }
        match self.config.mode {
            ProcessingMode::Exact => {
                let rows: Vec<usize> = (range.start..range.end).collect();
                (self.scan_rows(&rows, metrics), None)
            }
            ProcessingMode::Sampling { ratio } => {
                let local = sample_rows(range.len(), ratio, self.config.seed, part_id as u64);
                if local.is_empty() {
                    return (vec![Vec::new(); self.data.test.rows()], None);
                }
                let rows: Vec<usize> = local.iter().map(|&i| range.start + i).collect();
                (self.scan_rows(&rows, metrics), None)
            }
            ProcessingMode::AccurateML {
                compression_ratio,
                refinement_threshold,
            } => {
                let carry =
                    self.accurateml_carry(range, compression_ratio, refinement_threshold, metrics);
                let initial = self.initial_candidates(&carry, metrics);
                (initial, Some(carry))
            }
        }
    }

    fn stage2(&self, _part_id: usize, carry: KnnCarry, metrics: &mut TaskMetrics) -> Self::MapOut {
        self.accurateml_stage2(&carry, metrics)
    }

    fn reduce_ref(&self, outs: &[Self::MapOut]) -> KnnOutput {
        let n_test = self.data.test.rows();
        let mut predictions = Vec::with_capacity(n_test);
        for t in 0..n_test {
            let lists: Vec<Vec<LabeledCandidate>> = outs.iter().map(|o| o[t].clone()).collect();
            let merged = merge_candidates(&lists, self.config.k);
            predictions.push(majority_vote(&merged));
        }
        let accuracy = classification_accuracy(&predictions, &self.data.test_labels);
        KnnOutput {
            predictions,
            accuracy,
        }
    }

    fn evaluate(&self, output: &KnnOutput) -> f64 {
        output.accuracy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixtureSpec;
    use crate::mapreduce::engine::Engine;
    use crate::runtime::backend::NativeBackend;

    fn dataset() -> Arc<LabeledPoints> {
        Arc::new(
            GaussianMixtureSpec {
                n_points: 3000,
                dim: 12,
                n_classes: 5,
                noise: 0.35,
                test_fraction: 0.03,
                seed: 42,
                ..Default::default()
            }
            .generate()
            .unwrap(),
        )
    }

    fn run(
        mode: ProcessingMode,
        data: Arc<LabeledPoints>,
    ) -> (KnnOutput, crate::mapreduce::JobMetrics) {
        let engine = Engine::new(4);
        let job = KnnJob::new(
            KnnConfig {
                k: 5,
                n_partitions: 8,
                mode,
                seed: 7,
                ..Default::default()
            },
            data,
            Arc::new(NativeBackend),
        )
        .unwrap();
        let report = engine.run(Arc::new(job)).unwrap();
        (report.output, report.metrics)
    }

    #[test]
    fn exact_mode_is_accurate() {
        let data = dataset();
        let (out, metrics) = run(ProcessingMode::Exact, data.clone());
        assert!(out.accuracy > 0.85, "exact accuracy {}", out.accuracy);
        assert_eq!(out.predictions.len(), data.test.rows());
        // Shuffle: k candidates per test point per partition.
        assert_eq!(
            metrics.shuffle_records,
            (data.test.rows() * 5 * 8) as u64
        );
    }

    #[test]
    fn accurateml_close_to_exact_and_faster_records() {
        let data = dataset();
        let (exact, _) = run(ProcessingMode::Exact, data.clone());
        let (aml, metrics) = run(
            ProcessingMode::AccurateML {
                compression_ratio: 10.0,
                refinement_threshold: 0.1,
            },
            data.clone(),
        );
        let loss = classify::accuracy_loss(exact.accuracy, aml.accuracy);
        assert!(loss < 0.25, "accuracy loss too large: {loss}");
        // Aggregation parts were exercised and timed.
        let mean = metrics.mean_task();
        assert!(mean.lsh_s > 0.0);
        assert!(mean.aggregate_s > 0.0);
        assert!(mean.initial_s > 0.0);
    }

    #[test]
    fn accurateml_eps1_r1_recovers_exact() {
        // ratio→1 makes buckets near-singletons; ε=1 refines all of
        // them, so the result must equal the exact scan.
        let data = dataset();
        let (exact, _) = run(ProcessingMode::Exact, data.clone());
        let (aml, _) = run(
            ProcessingMode::AccurateML {
                compression_ratio: 1.0,
                refinement_threshold: 1.0,
            },
            data.clone(),
        );
        assert_eq!(exact.predictions, aml.predictions);
    }

    #[test]
    fn sampling_full_ratio_equals_exact() {
        let data = dataset();
        let (exact, _) = run(ProcessingMode::Exact, data.clone());
        let (sampled, _) = run(ProcessingMode::Sampling { ratio: 1.0 }, data);
        assert_eq!(exact.predictions, sampled.predictions);
    }

    #[test]
    fn sampling_low_ratio_degrades() {
        let data = dataset();
        let (exact, _) = run(ProcessingMode::Exact, data.clone());
        let (sampled, _) = run(ProcessingMode::Sampling { ratio: 0.02 }, data);
        assert!(sampled.accuracy <= exact.accuracy + 0.05);
    }
}
