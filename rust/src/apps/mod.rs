//! The two ML applications the paper evaluates (§III-D):
//!
//! * [`knn`] — kNN classification over labeled feature points;
//! * [`cf`] — user-based collaborative-filtering recommendation over a
//!   rating matrix.
//!
//! Each application implements [`crate::mapreduce::MapReduceJob`] once,
//! with [`crate::approx::ProcessingMode`] selecting between the exact
//! scan, AccurateML's Algorithm 1, and the sampling baseline inside the
//! map task — mirroring the paper's claim that adopting AccurateML
//! requires no change to the learning algorithm, only to the data fed
//! into it.

pub mod cf;
pub mod kmeans;
pub mod knn;

/// Queries per stage-2 block in the batch adapters: bounds the scored
/// rescan blocks a map task holds at once (memory ∝ chunk × refined
/// originals) while keeping enough queries per bucket-group to
/// amortize each backend call — the same micro-batch shape the serving
/// executor uses.
pub(crate) const STAGE2_BLOCK_QUERIES: usize = 256;
